//! Host-numerics expert-parallel MoE step: the engine's dispatch →
//! expert-FFN → combine hot path executed with in-process numerics on
//! the worker pool, independent of the PJRT artifacts.
//!
//! This is what `benches/perf_gate.rs` times ("engine steps", serial vs
//! parallel), what the `par_determinism` integration suite pins
//! bit-exact across thread counts, and what `examples/perfprobe.rs
//! --sim` instruments per phase. It reuses the artifact engine's exact
//! routing types ([`RoutingTable`], [`DispatchPlan`], [`Placement`]).
//!
//! Two executors share the same numerics (bit-exact against each other
//! and across pool widths):
//!
//! * **Barriered** ([`HostMoeLayer::step`]) — the DESIGN.md §8 baseline:
//!   dispatch, expert-FFN and combine run as three pool-wide phases with
//!   a barrier between each, experts statically chunked over workers.
//!   One hot expert stalls the whole pool at every barrier.
//! * **Overlapped** ([`HostMoeLayer::step_overlapped`]) — the DESIGN.md
//!   §10 executor: the per-expert chain gather→FFN→combine is fused into
//!   dynamically-scheduled tasks on [`ParPool::run_graph`]; oversized
//!   experts are row-split across idle workers, and each per-device
//!   combine starts the moment the experts *it* depends on finish — no
//!   global barrier anywhere. Determinism survives because results land
//!   in slots pre-indexed by (expert, row) and each device accumulates
//!   its disjoint output rows in fixed (expert asc, entry asc) order.
//!
//! [`HostMoeLayer::assemble`] splits the dispatch-payload staging out of
//! the step so `coordinator::pipeline::HostPipeline` can run it on a
//! comm sub-pool, overlapped with a neighbouring step's expert compute.

use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use crate::coordinator::buffers::TensorArena;
use crate::linalg;
use crate::par::{ParPool, TaskGraph};
use crate::rng::Rng;
use crate::tensor::{ops, Tensor};

use super::{DispatchEntry, DispatchPlan, Placement, RoutingTable};

/// In-place softmax over the last axis.
fn softmax_rows(t: &mut Tensor) {
    let (n, _) = t.rows();
    for i in 0..n {
        let row = t.row_mut(i);
        let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f32;
        for v in row.iter_mut() {
            *v = (*v - mx).exp();
            sum += *v;
        }
        for v in row.iter_mut() {
            *v /= sum;
        }
    }
}

/// One expert's FFN weights, stored in transposed-B layout (rows are
/// output channels) so both projections run through the cache-blocked
/// [`linalg::matmul_bt_with`] kernel without re-transposition.
#[derive(Debug, Clone)]
pub struct ExpertFfn {
    /// First projection, transposed: [d_ff, d_model].
    pub w1t: Tensor,
    /// Second projection, transposed: [d_model, d_ff].
    pub w2t: Tensor,
}

impl ExpertFfn {
    /// Synthesize 1/√fan-in scaled normal weights from a seed.
    pub fn synth(d_model: usize, d_ff: usize, seed: u64) -> ExpertFfn {
        let mut rng = Rng::new(seed);
        let mut w1t = Tensor::zeros(&[d_ff, d_model]);
        rng.fill_normal(w1t.data_mut());
        w1t.scale(1.0 / (d_model as f32).sqrt());
        let mut w2t = Tensor::zeros(&[d_model, d_ff]);
        rng.fill_normal(w2t.data_mut());
        w2t.scale(1.0 / (d_ff as f32).sqrt());
        ExpertFfn { w1t, w2t }
    }

    /// y = gelu(x · W1ᵀ) · W2ᵀ over [n, d_model] rows. The GELU runs as
    /// a fused epilogue of the first projection
    /// ([`linalg::matmul_bt_gelu_with`]) — bit-identical to a separate
    /// elementwise pass, without the extra sweep over the [n, d_ff]
    /// hidden activation.
    pub fn forward(&self, pool: &ParPool, x: &Tensor) -> Tensor {
        let h = linalg::matmul_bt_gelu_with(pool, x, &self.w1t);
        linalg::matmul_bt_with(pool, &h, &self.w2t)
    }
}

/// Shape of a host MoE layer.
#[derive(Debug, Clone, Copy)]
pub struct HostMoeConfig {
    /// Routed experts.
    pub n_experts: usize,
    /// Experts chosen per token.
    pub top_k: usize,
    /// Token width.
    pub d_model: usize,
    /// Expert FFN hidden width.
    pub d_ff: usize,
    /// Emulated devices (expert owners / token-shard owners).
    pub devices: usize,
}

/// A host MoE layer: router projection + per-expert FFNs + placement.
#[derive(Debug, Clone)]
pub struct HostMoeLayer {
    /// Layer shape.
    pub cfg: HostMoeConfig,
    /// Router projection, transposed-B layout: [n_experts, d_model].
    pub router_t: Tensor,
    /// One FFN per routed expert.
    pub experts: Vec<ExpertFfn>,
    placement: Placement,
}

/// Per-phase BUSY seconds plus wall-clock seconds of one host engine
/// step.
///
/// For the barriered executor the phases are sequential, so
/// `total_s() ≈ wall_s`. Under the overlapped executor the phases run
/// concurrently on the task crew: each phase field then accumulates the
/// busy time of every task of that kind, and the step obeys
/// `wall_s ≤ total_s()` (up to scheduling overhead) — the gap IS the
/// measured overlap. Phase times no longer sum to wall time by design;
/// report both (`perfprobe --sim` does).
#[derive(Debug, Clone, Copy, Default)]
pub struct HostPhases {
    /// Router probs + top-k table + dispatch plan (busy).
    pub route_s: f64,
    /// Per-expert token gather — the dispatch payload assembly (busy).
    pub dispatch_s: f64,
    /// Expert FFN execution (busy).
    pub expert_s: f64,
    /// Score-scaled scatter back to per-device token rows (busy).
    pub combine_s: f64,
    /// Wall-clock of the whole step (elapsed, not busy).
    pub wall_s: f64,
}

impl HostPhases {
    /// Sum of the four phase BUSY times. Equals elapsed time for the
    /// barriered executor only; compare against [`HostPhases::wall_s`]
    /// to see the overlap (`total_s / wall_s` > 1 means phases ran
    /// concurrently).
    pub fn total_s(&self) -> f64 {
        self.route_s + self.dispatch_s + self.expert_s + self.combine_s
    }

    /// Accumulate another step's phase + wall times into this one.
    pub fn accumulate(&mut self, o: &HostPhases) {
        self.route_s += o.route_s;
        self.dispatch_s += o.dispatch_s;
        self.expert_s += o.expert_s;
        self.combine_s += o.combine_s;
        self.wall_s += o.wall_s;
    }
}

/// A staged dispatch payload: every expert's token block already
/// gathered, with the routing entries that produced it. This is the
/// unit the staleness buffers hold — `HostPipeline` assembles it on the
/// comm sub-pool (possibly one step ahead) and feeds it to
/// [`HostMoeLayer::ffn_combine_overlapped`] on the compute sub-pool.
#[derive(Debug)]
pub struct HostDispatch {
    /// Entries grouped by destination expert; the append order (expert
    /// asc, entry asc) fixes the combine accumulation order.
    pub per_expert: Vec<Vec<DispatchEntry>>,
    /// Per-expert gathered token blocks [load_e, d_model] (arena slots).
    pub gathered: Vec<Tensor>,
    /// Diffusion step the payload was captured at (staleness age =
    /// consume step − this).
    pub captured_step: usize,
    /// Token count of the step the payload was gathered from.
    pub n_tokens: usize,
}

impl HostDispatch {
    /// Bytes held live by this payload (gathered activations + entry
    /// metadata) — the displaced-vs-interweaved buffer accounting unit.
    pub fn byte_size(&self) -> usize {
        let entries: usize = self.per_expert.iter().map(Vec::len).sum();
        self.gathered.iter().map(Tensor::byte_size).sum::<usize>()
            + entries * std::mem::size_of::<DispatchEntry>()
    }

    /// Return the gathered blocks to `arena` for the next assembly.
    pub fn recycle_into(self, arena: &mut TensorArena) {
        for t in self.gathered {
            arena.recycle(t);
        }
    }
}

/// Which memory the overlapped executor's fused gather stage reads
/// from: the raw step input (gather fused into the expert task), or a
/// pre-assembled payload's per-expert blocks.
#[derive(Clone, Copy)]
enum BlockSource<'a> {
    /// Gather straight from the [n_tokens, d_model] step input.
    Tokens(&'a Tensor),
    /// Stage from pre-gathered per-expert blocks ([`HostDispatch`]).
    Gathered(&'a [Tensor]),
}

/// One FFN subtask's result: the expert output rows it owns plus its
/// busy-time split.
struct SubOut {
    y: Tensor,
    gather_s: f64,
    ffn_s: f64,
}

impl HostMoeLayer {
    /// Synthesize a layer from a seed, with the contiguous baseline
    /// placement (remainders distributed — `devices` need not divide
    /// `n_experts`). Install a policy-solved map with
    /// [`HostMoeLayer::with_placement`].
    pub fn synth(cfg: HostMoeConfig, seed: u64) -> HostMoeLayer {
        let placement = Placement::new(cfg.n_experts, cfg.devices);
        let mut rng = Rng::new(seed ^ 0x9E37_79B9_7F4A_7C15);
        let mut router_t = Tensor::zeros(&[cfg.n_experts, cfg.d_model]);
        rng.fill_normal(router_t.data_mut());
        router_t.scale(1.0 / (cfg.d_model as f32).sqrt());
        let experts = (0..cfg.n_experts)
            .map(|e| ExpertFfn::synth(cfg.d_model, cfg.d_ff, seed.wrapping_add(1 + e as u64)))
            .collect();
        HostMoeLayer {
            cfg,
            router_t,
            experts,
            placement,
        }
    }

    /// The expert→device placement of this layer.
    pub fn placement(&self) -> &Placement {
        &self.placement
    }

    /// Install a (policy-solved) expert→device map. The layer's
    /// numerics are placement-INVARIANT — the combine scatters to
    /// token-owned rows, so only the crossing-bytes accounting
    /// ([`DispatchPlan::cross_bytes`] against [`HostMoeLayer::placement`])
    /// changes — which is exactly the property the determinism suite
    /// pins across placements and pool widths.
    pub fn with_placement(mut self, placement: Placement) -> HostMoeLayer {
        assert_eq!(placement.n_experts, self.cfg.n_experts, "placement expert count");
        assert_eq!(placement.devices, self.cfg.devices, "placement device count");
        self.placement = placement;
        self
    }

    /// The shared routing front end (router matmul → softmax → top-k):
    /// ONE definition used by [`HostMoeLayer::route`] (and through it
    /// every `step*` variant) and [`HostMoeLayer::assemble`], so the
    /// barriered, overlapped and pipeline paths cannot drift apart.
    fn route_table(&self, pool: &ParPool, x: &Tensor) -> RoutingTable {
        let mut logits = linalg::matmul_bt_with(pool, x, &self.router_t);
        softmax_rows(&mut logits);
        RoutingTable::from_probs(&logits, self.cfg.top_k)
    }

    /// Route `x` ([n_tokens, d_model]) and build the dispatch plan.
    pub fn route(&self, pool: &ParPool, x: &Tensor) -> (RoutingTable, DispatchPlan) {
        let (n_tokens, _) = x.rows();
        let routing = self.route_table(pool, x);
        let plan = DispatchPlan::build(&routing, n_tokens / self.cfg.devices);
        (routing, plan)
    }

    /// One dispatch→expert→combine engine step over [n_tokens, d_model]
    /// tokens (BARRIERED executor). `n_tokens` must split evenly over
    /// the devices. Bit-exact for any pool width: every output row is
    /// accumulated by exactly one worker in a fixed (expert, entry)
    /// order.
    pub fn step(&self, pool: &ParPool, x: &Tensor) -> Tensor {
        self.step_timed(pool, x).0
    }

    /// As [`HostMoeLayer::step`], also returning per-phase timings.
    pub fn step_timed(&self, pool: &ParPool, x: &Tensor) -> (Tensor, HostPhases) {
        self.step_inner(pool, x, None, false)
    }

    /// Barriered step with an INJECTED routing table (skewed-workload
    /// benches drive this with `placement::skewed_probs` routing instead
    /// of the layer's own router).
    pub fn step_routed_timed(
        &self,
        pool: &ParPool,
        x: &Tensor,
        routing: &RoutingTable,
    ) -> (Tensor, HostPhases) {
        self.step_inner(pool, x, Some(routing), false)
    }

    /// The one body behind all four public step entry points: shape
    /// check, route (or plan-build from an injected table) timed as
    /// `route_s`, then the chosen executor, with `wall_s` stamped over
    /// the whole step.
    fn step_inner(
        &self,
        pool: &ParPool,
        x: &Tensor,
        routing: Option<&RoutingTable>,
        overlapped: bool,
    ) -> (Tensor, HostPhases) {
        let t_all = Instant::now();
        self.check_step_shape(x);
        let (n_tokens, _) = x.rows();
        let t0 = Instant::now();
        let plan = match routing {
            Some(rt) => DispatchPlan::build(rt, n_tokens / self.cfg.devices),
            None => self.route(pool, x).1,
        };
        let route_s = t0.elapsed().as_secs_f64();
        let (out, mut ph) = if overlapped {
            self.run_overlapped(pool, &plan.per_expert, BlockSource::Tokens(x), n_tokens)
        } else {
            self.step_barriered_from_plan(pool, x, &plan.per_expert)
        };
        ph.route_s = route_s;
        ph.wall_s = t_all.elapsed().as_secs_f64();
        (out, ph)
    }

    fn check_step_shape(&self, x: &Tensor) {
        let (n_tokens, d) = x.rows();
        assert_eq!(d, self.cfg.d_model, "token width {d} != d_model");
        assert!(
            n_tokens % self.cfg.devices == 0 && n_tokens >= self.cfg.devices,
            "tokens {n_tokens} must split evenly over {} devices",
            self.cfg.devices
        );
    }

    /// The three barriered phases (dispatch gather / expert FFN /
    /// combine) over an already-built plan. Static chunking, one
    /// barrier between each phase — the baseline the overlapped
    /// executor is gated against.
    fn step_barriered_from_plan(
        &self,
        pool: &ParPool,
        x: &Tensor,
        per_expert: &[Vec<DispatchEntry>],
    ) -> (Tensor, HostPhases) {
        let (n_tokens, _) = x.rows();
        let mut ph = HostPhases::default();

        // dispatch: assemble each expert's token block (parallel fan-out
        // over experts — the all-to-all send side).
        let t0 = Instant::now();
        let gathered: Vec<Tensor> = pool.map(per_expert, |_, entries| {
            let idx: Vec<usize> = entries.iter().map(|en| en.token).collect();
            ops::gather_rows(x, &idx)
        });
        ph.dispatch_s = t0.elapsed().as_secs_f64();

        // expert FFNs: one worker per expert; the inner matmuls run
        // serially inside the worker — the expert fan-out IS the
        // device-parallel axis.
        let t0 = Instant::now();
        let serial = ParPool::new(1);
        let outputs: Vec<Tensor> =
            pool.map(&gathered, |e, g| self.experts[e].forward(&serial, g));
        ph.expert_s = t0.elapsed().as_secs_f64();

        let t0 = Instant::now();
        let out = self.combine_barriered(pool, per_expert, &outputs, n_tokens);
        ph.combine_s = t0.elapsed().as_secs_f64();
        (out, ph)
    }

    /// The barriered combine: pool barrier; device `dev` owns output
    /// rows [dev·tpd, (dev+1)·tpd) and walks only ITS bucket of
    /// (expert, row) pairs, whose append order (expert asc, entry asc)
    /// fixes the per-row accumulation order — disjoint writes,
    /// deterministic sums, each entry touched exactly once.
    fn combine_barriered(
        &self,
        pool: &ParPool,
        per_expert: &[Vec<DispatchEntry>],
        outputs: &[Tensor],
        n_tokens: usize,
    ) -> Tensor {
        let d = self.cfg.d_model;
        let tokens_per_dev = n_tokens / self.cfg.devices;
        let mut dev_entries: Vec<Vec<(usize, usize)>> = vec![Vec::new(); self.cfg.devices];
        for (e, entries) in per_expert.iter().enumerate() {
            for (r, en) in entries.iter().enumerate() {
                dev_entries[en.token / tokens_per_dev].push((e, r));
            }
        }
        let mut out = Tensor::zeros(&[n_tokens, d]);
        let de = &dev_entries;
        let kern = linalg::simd::active();
        pool.for_chunks_mut(out.data_mut(), tokens_per_dev * d, |dev, chunk| {
            let t_lo = dev * tokens_per_dev;
            for &(e, r) in &de[dev] {
                let en = &per_expert[e][r];
                let at = (en.token - t_lo) * d;
                kern.axpy(&mut chunk[at..at + d], en.score, outputs[e].row(r));
            }
        });
        out
    }

    // ------------------------------------------------------------------
    // Overlapped executor (DESIGN.md §10)
    // ------------------------------------------------------------------

    /// One engine step on the OVERLAPPED executor: gather→FFN→combine
    /// fused into dynamically-scheduled tasks, oversized experts
    /// row-split, per-device combines dependency-chained — no global
    /// phase barrier. Bit-exact against [`HostMoeLayer::step`] for any
    /// pool width.
    pub fn step_overlapped(&self, pool: &ParPool, x: &Tensor) -> Tensor {
        self.step_overlapped_timed(pool, x).0
    }

    /// As [`HostMoeLayer::step_overlapped`], also returning per-phase
    /// BUSY timings plus the step's wall time (see [`HostPhases`]).
    pub fn step_overlapped_timed(&self, pool: &ParPool, x: &Tensor) -> (Tensor, HostPhases) {
        self.step_inner(pool, x, None, true)
    }

    /// Overlapped step with an INJECTED routing table (the skewed
    /// workload of the `pipeline_overlap` perf gate).
    pub fn step_overlapped_routed_timed(
        &self,
        pool: &ParPool,
        x: &Tensor,
        routing: &RoutingTable,
    ) -> (Tensor, HostPhases) {
        self.step_inner(pool, x, Some(routing), true)
    }

    /// Stage a dispatch payload from `x`: route on `pool` (the shared
    /// front end of [`HostMoeLayer::route`]), then gather every
    /// expert's token block into recycled `arena` slots. Slot pre-take
    /// is single-threaded (the arena is `&mut`), the row memcpys fan
    /// out over `pool` — and the path needs no per-step index buffers
    /// at all (rows are copied straight from the plan entries), so a
    /// warm steady-state assembly allocates nothing.
    pub fn assemble(
        &self,
        pool: &ParPool,
        x: &Tensor,
        step: usize,
        arena: &mut TensorArena,
    ) -> (HostDispatch, HostPhases) {
        self.check_step_shape(x);
        let t0 = Instant::now();
        let routing = self.route_table(pool, x);
        let route_s = t0.elapsed().as_secs_f64();
        let (disp, mut ph) = self.assemble_routed(pool, x, &routing, step, arena);
        ph.route_s += route_s;
        (disp, ph)
    }

    /// As [`HostMoeLayer::assemble`] with an injected routing table.
    pub fn assemble_routed(
        &self,
        pool: &ParPool,
        x: &Tensor,
        routing: &RoutingTable,
        step: usize,
        arena: &mut TensorArena,
    ) -> (HostDispatch, HostPhases) {
        self.check_step_shape(x);
        let (n_tokens, d) = x.rows();
        let mut ph = HostPhases::default();
        let t0 = Instant::now();
        let mut plan = DispatchPlan::build(routing, n_tokens / self.cfg.devices);
        ph.route_s = t0.elapsed().as_secs_f64();
        let t0 = Instant::now();
        let per_expert = std::mem::take(&mut plan.per_expert);
        let mut gathered: Vec<Tensor> = per_expert
            .iter()
            .map(|entries| arena.take(&[entries.len(), d]))
            .collect();
        // fill the disjoint slots over the pool, one task per expert
        // block; row order is the entry order, so the result is
        // bit-identical for any pool width.
        let pe = &per_expert;
        let kern = linalg::simd::active();
        pool.for_chunks_mut(&mut gathered, 1, |e, slot| {
            let g = &mut slot[0];
            for (o, en) in pe[e].iter().enumerate() {
                kern.copy(g.row_mut(o), x.row(en.token));
            }
        });
        ph.dispatch_s = t0.elapsed().as_secs_f64();
        (
            HostDispatch {
                per_expert,
                gathered,
                captured_step: step,
                n_tokens,
            },
            ph,
        )
    }

    /// Expert-FFN + combine of a staged payload on the OVERLAPPED
    /// executor (the pipeline's compute side; the gather already
    /// happened at assembly).
    pub fn ffn_combine_overlapped(
        &self,
        pool: &ParPool,
        disp: &HostDispatch,
    ) -> (Tensor, HostPhases) {
        let t_all = Instant::now();
        let (out, mut ph) = self.run_overlapped(
            pool,
            &disp.per_expert,
            BlockSource::Gathered(&disp.gathered),
            disp.n_tokens,
        );
        ph.wall_s = t_all.elapsed().as_secs_f64();
        (out, ph)
    }

    /// Expert-FFN + combine of a staged payload on the BARRIERED
    /// executor (static chunking + phase barriers) — the pipeline's
    /// `--pipeline barriered` reference path.
    pub fn ffn_combine_barriered(
        &self,
        pool: &ParPool,
        disp: &HostDispatch,
    ) -> (Tensor, HostPhases) {
        let t_all = Instant::now();
        let mut ph = HostPhases::default();
        let t0 = Instant::now();
        let serial = ParPool::new(1);
        let outputs: Vec<Tensor> =
            pool.map(&disp.gathered, |e, g| self.experts[e].forward(&serial, g));
        ph.expert_s = t0.elapsed().as_secs_f64();
        let t0 = Instant::now();
        let out = self.combine_barriered(pool, &disp.per_expert, &outputs, disp.n_tokens);
        ph.combine_s = t0.elapsed().as_secs_f64();
        ph.wall_s = t_all.elapsed().as_secs_f64();
        (out, ph)
    }

    /// The overlapped task crew: one fused gather→FFN task per expert
    /// row-slice, one combine task per device, dependency edges from
    /// each slice to exactly the devices its rows scatter into, all
    /// executed by [`ParPool::run_graph`]'s dynamic queue.
    ///
    /// Determinism (DESIGN.md §10): FFN results land in slots
    /// pre-indexed by subtask id; each device accumulates its DISJOINT
    /// block of output rows walking its entry bucket in (expert asc,
    /// entry asc) order — identical to the barriered combine order —
    /// so the output is bit-exact vs [`HostMoeLayer::step`] for any
    /// pool width and any completion order. Row-splitting cannot change
    /// bits either: each output row of the blocked matmul kernel
    /// depends only on its own input row.
    fn run_overlapped(
        &self,
        pool: &ParPool,
        per_expert: &[Vec<DispatchEntry>],
        source: BlockSource<'_>,
        n_tokens: usize,
    ) -> (Tensor, HostPhases) {
        let d = self.cfg.d_model;
        let devices = self.cfg.devices;
        assert!(n_tokens % devices == 0 && n_tokens >= devices, "token shard shape");
        let tpd = n_tokens / devices;

        // row-split layout: aim for ~2 slices per worker so a hot
        // expert spreads over idle workers; the floor keeps tiny blocks
        // whole. The split factor may depend on the pool width — bits
        // cannot (per-row independence above).
        let total: usize = per_expert.iter().map(Vec::len).sum();
        let target = total.div_ceil(2 * pool.threads().max(1)).max(8);
        let n_experts = per_expert.len();
        let mut sub_base = vec![0usize; n_experts];
        let mut sub_rows = vec![0usize; n_experts];
        let mut sub_expert: Vec<usize> = Vec::new();
        let mut sub_lo: Vec<usize> = Vec::new();
        let mut sub_hi: Vec<usize> = Vec::new();
        for (e, entries) in per_expert.iter().enumerate() {
            sub_base[e] = sub_expert.len();
            let n_e = entries.len();
            sub_rows[e] = target.min(n_e.max(1));
            let mut lo = 0usize;
            while lo < n_e {
                let hi = (lo + sub_rows[e]).min(n_e);
                sub_expert.push(e);
                sub_lo.push(lo);
                sub_hi.push(hi);
                lo = hi;
            }
        }
        let n_subs = sub_expert.len();

        // task graph: subtasks 0..n_subs, then one combine per device.
        // A device depends on exactly the slices whose rows it scatters;
        // per device the slice sequence is nondecreasing (entries walk
        // expert asc, row asc), so dedupe needs only the last id.
        let mut graph = TaskGraph::new(n_subs + devices);
        let mut dev_entries: Vec<Vec<(usize, usize)>> = vec![Vec::new(); devices];
        let mut last_sub: Vec<usize> = vec![usize::MAX; devices];
        for (e, entries) in per_expert.iter().enumerate() {
            for (r, en) in entries.iter().enumerate() {
                let dev = en.token / tpd;
                let sub = sub_base[e] + r / sub_rows[e];
                if last_sub[dev] != sub {
                    graph.edge(sub, n_subs + dev);
                    last_sub[dev] = sub;
                }
                dev_entries[dev].push((e, r));
            }
        }

        let outs: Vec<OnceLock<SubOut>> = (0..n_subs).map(|_| OnceLock::new()).collect();
        let dev_s: Vec<OnceLock<f64>> = (0..devices).map(|_| OnceLock::new()).collect();
        let mut out = Tensor::zeros(&[n_tokens, d]);
        let serial = ParPool::new(1);
        let kern = linalg::simd::active();
        {
            // each device task locks exactly its own chunk, exactly
            // once — the Mutex is an ownership handover, not contention
            let chunks: Vec<Mutex<&mut [f32]>> =
                out.data_mut().chunks_mut(tpd * d).map(Mutex::new).collect();
            let run = |task: usize| {
                if task < n_subs {
                    let e = sub_expert[task];
                    let (lo, hi) = (sub_lo[task], sub_hi[task]);
                    let t0 = Instant::now();
                    // a pre-gathered block consumed whole (the common,
                    // un-split case) is borrowed directly — the payload
                    // is NOT copied a second time; only row-split slices
                    // and fused-gather tasks stage into a local block.
                    let staged: Option<Tensor> = match source {
                        BlockSource::Gathered(_) if lo == 0 && hi == per_expert[e].len() => None,
                        BlockSource::Gathered(g) => {
                            let mut b = Tensor::zeros(&[hi - lo, d]);
                            kern.copy(b.data_mut(), &g[e].data()[lo * d..hi * d]);
                            Some(b)
                        }
                        BlockSource::Tokens(x) => {
                            let mut b = Tensor::zeros(&[hi - lo, d]);
                            for (o, en) in per_expert[e][lo..hi].iter().enumerate() {
                                kern.copy(b.row_mut(o), x.row(en.token));
                            }
                            Some(b)
                        }
                    };
                    let block: &Tensor = match (&staged, source) {
                        (Some(b), _) => b,
                        (None, BlockSource::Gathered(g)) => &g[e],
                        (None, BlockSource::Tokens(_)) => {
                            unreachable!("fused gather always stages")
                        }
                    };
                    let gather_s = t0.elapsed().as_secs_f64();
                    let t1 = Instant::now();
                    let h = linalg::matmul_bt_gelu_with(&serial, block, &self.experts[e].w1t);
                    let y = linalg::matmul_bt_with(&serial, &h, &self.experts[e].w2t);
                    let ffn_s = t1.elapsed().as_secs_f64();
                    let _ = outs[task].set(SubOut { y, gather_s, ffn_s });
                } else {
                    let dev = task - n_subs;
                    let t0 = Instant::now();
                    let mut guard = chunks[dev].lock().expect("combine chunk lock");
                    let chunk: &mut [f32] = &mut guard;
                    let t_lo = dev * tpd;
                    for &(e, r) in &dev_entries[dev] {
                        let en = &per_expert[e][r];
                        let sub = sub_base[e] + r / sub_rows[e];
                        let so = outs[sub].get().expect("dependency completed");
                        let local = r - sub_lo[sub];
                        let at = (en.token - t_lo) * d;
                        kern.axpy(&mut chunk[at..at + d], en.score, so.y.row(local));
                    }
                    let _ = dev_s[dev].set(t0.elapsed().as_secs_f64());
                }
            };
            pool.run_graph(&graph, run);
        }

        let mut ph = HostPhases::default();
        for o in &outs {
            let so = o.get().expect("all subtasks ran");
            ph.dispatch_s += so.gather_s;
            ph.expert_s += so.ffn_s;
        }
        for s in &dev_s {
            ph.combine_s += s.get().copied().unwrap_or(0.0);
        }
        (out, ph)
    }
}

/// An `n_layers` stack of host MoE layers — the unit the multi-layer
/// [`HostPipeline`] drives (DESIGN.md §11). All layers share one shape
/// (`d_model` / `devices`) so a step's latent flows through the whole
/// chain; router and expert weights differ per layer.
///
/// [`HostPipeline`]: crate::coordinator::HostPipeline
#[derive(Debug, Clone)]
pub struct HostMoeStack {
    layers: Vec<HostMoeLayer>,
}

impl HostMoeStack {
    /// Synthesize `n_layers` layers of shape `cfg` with per-layer
    /// derived seeds (each layer routes and computes differently).
    pub fn synth(cfg: HostMoeConfig, n_layers: usize, seed: u64) -> HostMoeStack {
        assert!(n_layers >= 1, "a stack needs at least one layer");
        let layers = (0..n_layers as u64)
            .map(|l| HostMoeLayer::synth(cfg, seed.wrapping_add(l.wrapping_mul(0x9E37_79B9))))
            .collect();
        HostMoeStack { layers }
    }

    /// Wrap existing layers (all must share `d_model` and `devices`).
    pub fn from_layers(layers: Vec<HostMoeLayer>) -> HostMoeStack {
        assert!(!layers.is_empty(), "a stack needs at least one layer");
        let (d, dev) = (layers[0].cfg.d_model, layers[0].cfg.devices);
        assert!(
            layers.iter().all(|l| l.cfg.d_model == d && l.cfg.devices == dev),
            "stack layers must agree on d_model and devices"
        );
        HostMoeStack { layers }
    }

    /// Number of layers.
    pub fn n_layers(&self) -> usize {
        self.layers.len()
    }

    /// Layer `l`.
    pub fn layer(&self, l: usize) -> &HostMoeLayer {
        &self.layers[l]
    }

    /// All layers, in execution order.
    pub fn layers(&self) -> &[HostMoeLayer] {
        &self.layers
    }

    /// The shared shape (of layer 0; all layers agree on
    /// `d_model`/`devices` by construction).
    pub fn cfg(&self) -> &HostMoeConfig {
        &self.layers[0].cfg
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layer() -> HostMoeLayer {
        HostMoeLayer::synth(
            HostMoeConfig {
                n_experts: 8,
                top_k: 2,
                d_model: 16,
                d_ff: 32,
                devices: 4,
            },
            0xD1CE,
        )
    }

    fn tokens(n: usize, d: usize, seed: u64) -> Tensor {
        let mut x = Tensor::zeros(&[n, d]);
        Rng::new(seed).fill_normal(x.data_mut());
        x
    }

    #[test]
    fn stack_layers_are_distinct_but_share_shape() {
        let cfg = HostMoeConfig {
            n_experts: 8,
            top_k: 2,
            d_model: 16,
            d_ff: 32,
            devices: 4,
        };
        let stack = HostMoeStack::synth(cfg, 3, 0xD1CE);
        assert_eq!(stack.n_layers(), 3);
        let x = tokens(16, 16, 3);
        let pool = ParPool::new(2);
        let y0 = stack.layer(0).step(&pool, &x);
        let y1 = stack.layer(1).step(&pool, &x);
        assert_eq!(y0.shape(), y1.shape());
        assert_ne!(y0, y1, "per-layer seeds must differ");
        // single-layer wrap preserves the layer
        let one = HostMoeStack::from_layers(vec![layer()]);
        assert_eq!(one.n_layers(), 1);
        assert_eq!(one.layer(0).step(&pool, &x), layer().step(&pool, &x));
    }

    #[test]
    #[should_panic(expected = "agree on d_model")]
    fn stack_rejects_mismatched_shapes() {
        let a = layer();
        let b = HostMoeLayer::synth(
            HostMoeConfig {
                n_experts: 8,
                top_k: 2,
                d_model: 32,
                d_ff: 32,
                devices: 4,
            },
            1,
        );
        HostMoeStack::from_layers(vec![a, b]);
    }

    #[test]
    fn step_shape_and_coverage() {
        let l = layer();
        let x = tokens(32, 16, 1);
        let (out, ph) = l.step_timed(&ParPool::new(2), &x);
        assert_eq!(out.shape(), &[32, 16]);
        assert!(out.data().iter().any(|&v| v != 0.0));
        assert!(ph.total_s() >= 0.0);
        // every token got top_k expert contributions
        let (routing, plan) = l.route(&ParPool::new(1), &x);
        assert_eq!(routing.top_k, 2);
        assert_eq!(plan.total_entries(), 32 * 2);
    }

    #[test]
    fn step_is_bit_exact_across_pool_widths() {
        let l = layer();
        let x = tokens(64, 16, 7);
        let serial = l.step(&ParPool::new(1), &x);
        for t in [2usize, 4, 8] {
            assert_eq!(serial, l.step(&ParPool::new(t), &x), "threads={t}");
        }
    }

    #[test]
    fn empty_experts_are_tolerated() {
        // top-1 routing over many experts leaves some experts with no
        // tokens; their gather/FFN blocks are [0, d] and must no-op.
        let l = HostMoeLayer::synth(
            HostMoeConfig {
                n_experts: 16,
                top_k: 1,
                d_model: 8,
                d_ff: 16,
                devices: 2,
            },
            3,
        );
        let x = tokens(4, 8, 11);
        let out = l.step(&ParPool::new(4), &x);
        assert_eq!(out.shape(), &[4, 8]);
    }

    #[test]
    fn non_divisible_devices_and_policy_maps_are_tolerated() {
        // 6 experts over 4 devices: remainder layout 2-2-1-1 instead of
        // the old divisibility panic; and an installed policy map
        // changes only the accounting, never the numerics.
        let l = HostMoeLayer::synth(
            HostMoeConfig {
                n_experts: 6,
                top_k: 2,
                d_model: 8,
                d_ff: 16,
                devices: 4,
            },
            5,
        );
        assert_eq!(l.placement().experts_of(0), vec![0, 1]);
        assert_eq!(l.placement().experts_of(3), vec![5]);
        let x = tokens(8, 8, 3);
        let out = l.step(&ParPool::new(2), &x);
        let scrambled = Placement::from_owner(4, vec![3, 2, 1, 0, 0, 1]);
        let l2 = l.clone().with_placement(scrambled);
        assert_eq!(out, l2.step(&ParPool::new(2), &x), "numerics are placement-invariant");
    }

    #[test]
    fn overlapped_step_is_bit_exact_vs_barriered() {
        let l = layer();
        let x = tokens(64, 16, 9);
        let want = l.step(&ParPool::new(1), &x);
        for t in [1usize, 2, 4, 8] {
            let (got, ph) = l.step_overlapped_timed(&ParPool::new(t), &x);
            assert_eq!(want, got, "threads={t}");
            assert!(ph.wall_s > 0.0 && ph.total_s() > 0.0);
        }
    }

    #[test]
    fn overlapped_step_matches_barriered_on_skewed_routing() {
        // injected skewed routing: one hot expert, exactly the case the
        // dynamic row-split exists for
        let l = layer();
        let x = tokens(64, 16, 31);
        let probs = crate::placement::skewed_probs(64, l.cfg.n_experts, l.cfg.devices, 0xBEEF);
        let routing = RoutingTable::from_probs(&probs, l.cfg.top_k);
        let (want, _) = l.step_routed_timed(&ParPool::new(1), &x, &routing);
        for t in [1usize, 2, 4] {
            let (got, _) = l.step_overlapped_routed_timed(&ParPool::new(t), &x, &routing);
            assert_eq!(want, got, "threads={t}");
            let (got_b, _) = l.step_routed_timed(&ParPool::new(t), &x, &routing);
            assert_eq!(want, got_b, "barriered threads={t}");
        }
    }

    #[test]
    fn assembled_payload_reproduces_the_fused_step() {
        let l = layer();
        let x = tokens(32, 16, 13);
        let want = l.step(&ParPool::new(1), &x);
        let pool = ParPool::new(3);
        let mut arena = TensorArena::new();
        let (disp, ph_a) = l.assemble(&pool, &x, 7, &mut arena);
        assert_eq!(disp.captured_step, 7);
        assert!(disp.byte_size() > 0);
        assert!(ph_a.route_s >= 0.0 && ph_a.dispatch_s >= 0.0);
        // the staged payload's routing is EXACTLY what route() builds —
        // the two paths share one routing front end and cannot drift
        let (_rt, plan) = l.route(&ParPool::new(1), &x);
        assert_eq!(disp.per_expert, plan.per_expert);
        let (via_overlap, _) = l.ffn_combine_overlapped(&pool, &disp);
        assert_eq!(want, via_overlap, "pre-assembled overlapped");
        let (via_barrier, _) = l.ffn_combine_barriered(&pool, &disp);
        assert_eq!(want, via_barrier, "pre-assembled barriered");
        // recycling hands every gathered block back to the arena
        let blocks = disp.gathered.len();
        disp.recycle_into(&mut arena);
        assert_eq!(arena.free_slots(), blocks);
        // a second assembly round reuses those slots (warm free list)
        let (disp2, _) = l.assemble(&pool, &x, 8, &mut arena);
        assert!(arena.hits > 0, "warm assembly must hit the free list");
        disp2.recycle_into(&mut arena);
    }

    #[test]
    fn phase_accounting_includes_wall() {
        let l = layer();
        let x = tokens(32, 16, 2);
        let (_, ph) = l.step_timed(&ParPool::new(2), &x);
        // barriered: phases are sequential, wall covers their sum
        assert!(ph.wall_s >= ph.total_s() * 0.5, "wall {} vs busy {}", ph.wall_s, ph.total_s());
        let mut acc = HostPhases::default();
        acc.accumulate(&ph);
        acc.accumulate(&ph);
        assert!((acc.wall_s - 2.0 * ph.wall_s).abs() < 1e-12);
        assert!((acc.total_s() - 2.0 * ph.total_s()).abs() < 1e-9);
    }

    #[test]
    fn expert_ffn_matches_manual_small_case() {
        let ffn = ExpertFfn {
            w1t: Tensor::from_vec(&[2, 2], vec![1.0, 0.0, 0.0, 1.0]), // identity
            w2t: Tensor::from_vec(&[2, 2], vec![2.0, 0.0, 0.0, 2.0]), // 2·identity
        };
        let x = Tensor::from_vec(&[1, 2], vec![3.0, -3.0]);
        let y = ffn.forward(&ParPool::new(1), &x);
        // gelu(3) ≈ 2.9964, gelu(-3) ≈ -0.00363; doubled by w2
        assert!((y.data()[0] - 2.0 * 2.9964).abs() < 1e-2, "{:?}", y.data());
        assert!((y.data()[1] + 2.0 * 0.00363).abs() < 1e-2, "{:?}", y.data());
    }
}
