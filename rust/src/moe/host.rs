//! Host-numerics expert-parallel MoE step: the engine's dispatch →
//! expert-FFN → combine hot path executed with in-process numerics on
//! the worker pool, independent of the PJRT artifacts.
//!
//! This is what `benches/perf_gate.rs` times ("engine steps", serial vs
//! parallel), what the `par_determinism` integration suite pins
//! bit-exact across thread counts, and what `examples/perfprobe.rs
//! --sim` instruments per phase. It reuses the artifact engine's exact
//! routing types ([`RoutingTable`], [`DispatchPlan`], [`Placement`]),
//! and its parallel decomposition mirrors `coordinator::Engine::ep_moe`
//! one-to-one: experts fan out across workers, the combine is a pool
//! barrier, and each emulated device owns a disjoint block of output
//! token rows (DESIGN.md §8).

use std::time::Instant;

use crate::linalg;
use crate::par::ParPool;
use crate::rng::Rng;
use crate::tensor::{ops, Tensor};

use super::{DispatchPlan, Placement, RoutingTable};

/// tanh-approximation GELU (the same form the Pallas expert kernel
/// lowers, `python/compile/kernels/expert_ffn.py`).
fn gelu(x: f32) -> f32 {
    0.5 * x * (1.0 + (0.797_884_6 * (x + 0.044715 * x * x * x)).tanh())
}

/// In-place softmax over the last axis.
fn softmax_rows(t: &mut Tensor) {
    let (n, _) = t.rows();
    for i in 0..n {
        let row = t.row_mut(i);
        let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f32;
        for v in row.iter_mut() {
            *v = (*v - mx).exp();
            sum += *v;
        }
        for v in row.iter_mut() {
            *v /= sum;
        }
    }
}

/// One expert's FFN weights, stored in transposed-B layout (rows are
/// output channels) so both projections run through the cache-blocked
/// [`linalg::matmul_bt_with`] kernel without re-transposition.
#[derive(Debug, Clone)]
pub struct ExpertFfn {
    /// First projection, transposed: [d_ff, d_model].
    pub w1t: Tensor,
    /// Second projection, transposed: [d_model, d_ff].
    pub w2t: Tensor,
}

impl ExpertFfn {
    /// Synthesize 1/√fan-in scaled normal weights from a seed.
    pub fn synth(d_model: usize, d_ff: usize, seed: u64) -> ExpertFfn {
        let mut rng = Rng::new(seed);
        let mut w1t = Tensor::zeros(&[d_ff, d_model]);
        rng.fill_normal(w1t.data_mut());
        w1t.scale(1.0 / (d_model as f32).sqrt());
        let mut w2t = Tensor::zeros(&[d_model, d_ff]);
        rng.fill_normal(w2t.data_mut());
        w2t.scale(1.0 / (d_ff as f32).sqrt());
        ExpertFfn { w1t, w2t }
    }

    /// y = gelu(x · W1ᵀ) · W2ᵀ over [n, d_model] rows.
    pub fn forward(&self, pool: &ParPool, x: &Tensor) -> Tensor {
        let mut h = linalg::matmul_bt_with(pool, x, &self.w1t);
        for v in h.data_mut() {
            *v = gelu(*v);
        }
        linalg::matmul_bt_with(pool, &h, &self.w2t)
    }
}

/// Shape of a host MoE layer.
#[derive(Debug, Clone, Copy)]
pub struct HostMoeConfig {
    /// Routed experts.
    pub n_experts: usize,
    /// Experts chosen per token.
    pub top_k: usize,
    /// Token width.
    pub d_model: usize,
    /// Expert FFN hidden width.
    pub d_ff: usize,
    /// Emulated devices (expert owners / token-shard owners).
    pub devices: usize,
}

/// A host MoE layer: router projection + per-expert FFNs + placement.
#[derive(Debug, Clone)]
pub struct HostMoeLayer {
    /// Layer shape.
    pub cfg: HostMoeConfig,
    /// Router projection, transposed-B layout: [n_experts, d_model].
    pub router_t: Tensor,
    /// One FFN per routed expert.
    pub experts: Vec<ExpertFfn>,
    placement: Placement,
}

/// Wall-clock seconds per phase of one host engine step.
#[derive(Debug, Clone, Copy, Default)]
pub struct HostPhases {
    /// Router probs + top-k table + dispatch plan.
    pub route_s: f64,
    /// Per-expert token gather (the dispatch payload assembly).
    pub dispatch_s: f64,
    /// Expert FFN execution.
    pub expert_s: f64,
    /// Score-scaled scatter back to per-device token rows (pool barrier).
    pub combine_s: f64,
}

impl HostPhases {
    /// Sum of all four phases.
    pub fn total_s(&self) -> f64 {
        self.route_s + self.dispatch_s + self.expert_s + self.combine_s
    }

    /// Accumulate another step's phase times into this one.
    pub fn accumulate(&mut self, o: &HostPhases) {
        self.route_s += o.route_s;
        self.dispatch_s += o.dispatch_s;
        self.expert_s += o.expert_s;
        self.combine_s += o.combine_s;
    }
}

impl HostMoeLayer {
    /// Synthesize a layer from a seed, with the contiguous baseline
    /// placement (remainders distributed — `devices` need not divide
    /// `n_experts`). Install a policy-solved map with
    /// [`HostMoeLayer::with_placement`].
    pub fn synth(cfg: HostMoeConfig, seed: u64) -> HostMoeLayer {
        let placement = Placement::new(cfg.n_experts, cfg.devices);
        let mut rng = Rng::new(seed ^ 0x9E37_79B9_7F4A_7C15);
        let mut router_t = Tensor::zeros(&[cfg.n_experts, cfg.d_model]);
        rng.fill_normal(router_t.data_mut());
        router_t.scale(1.0 / (cfg.d_model as f32).sqrt());
        let experts = (0..cfg.n_experts)
            .map(|e| ExpertFfn::synth(cfg.d_model, cfg.d_ff, seed.wrapping_add(1 + e as u64)))
            .collect();
        HostMoeLayer {
            cfg,
            router_t,
            experts,
            placement,
        }
    }

    /// The expert→device placement of this layer.
    pub fn placement(&self) -> &Placement {
        &self.placement
    }

    /// Install a (policy-solved) expert→device map. The layer's
    /// numerics are placement-INVARIANT — the combine scatters to
    /// token-owned rows, so only the crossing-bytes accounting
    /// ([`DispatchPlan::cross_bytes`] against [`HostMoeLayer::placement`])
    /// changes — which is exactly the property the determinism suite
    /// pins across placements and pool widths.
    pub fn with_placement(mut self, placement: Placement) -> HostMoeLayer {
        assert_eq!(placement.n_experts, self.cfg.n_experts, "placement expert count");
        assert_eq!(placement.devices, self.cfg.devices, "placement device count");
        self.placement = placement;
        self
    }

    /// Route `x` ([n_tokens, d_model]) and build the dispatch plan.
    pub fn route(&self, pool: &ParPool, x: &Tensor) -> (RoutingTable, DispatchPlan) {
        let (n_tokens, _) = x.rows();
        let mut logits = linalg::matmul_bt_with(pool, x, &self.router_t);
        softmax_rows(&mut logits);
        let routing = RoutingTable::from_probs(&logits, self.cfg.top_k);
        let plan = DispatchPlan::build(&routing, n_tokens / self.cfg.devices);
        (routing, plan)
    }

    /// One dispatch→expert→combine engine step over [n_tokens, d_model]
    /// tokens. `n_tokens` must split evenly over the devices. Bit-exact
    /// for any pool width: every output row is accumulated by exactly
    /// one worker in a fixed (expert, entry) order.
    pub fn step(&self, pool: &ParPool, x: &Tensor) -> Tensor {
        self.step_timed(pool, x).0
    }

    /// As [`HostMoeLayer::step`], also returning per-phase timings.
    pub fn step_timed(&self, pool: &ParPool, x: &Tensor) -> (Tensor, HostPhases) {
        let (n_tokens, d) = x.rows();
        assert_eq!(d, self.cfg.d_model, "token width {d} != d_model");
        assert_eq!(
            n_tokens % self.cfg.devices,
            0,
            "tokens {n_tokens} % devices {} != 0",
            self.cfg.devices
        );
        let tokens_per_dev = n_tokens / self.cfg.devices;
        let mut ph = HostPhases::default();

        let t0 = Instant::now();
        let (_routing, plan) = self.route(pool, x);
        ph.route_s = t0.elapsed().as_secs_f64();
        // Only the Sync field escapes into pool closures: &DispatchPlan
        // itself is !Sync (the cross-bytes memo cell).
        let per_expert = &plan.per_expert;

        // dispatch: assemble each expert's token block (parallel fan-out
        // over experts — the all-to-all send side).
        let t0 = Instant::now();
        let gathered: Vec<Tensor> = pool.map(per_expert, |_, entries| {
            let idx: Vec<usize> = entries.iter().map(|en| en.token).collect();
            ops::gather_rows(x, &idx)
        });
        ph.dispatch_s = t0.elapsed().as_secs_f64();

        // expert FFNs: one worker per expert; the inner matmuls run
        // serially inside the worker — the expert fan-out IS the
        // device-parallel axis.
        let t0 = Instant::now();
        let serial = ParPool::new(1);
        let outputs: Vec<Tensor> =
            pool.map(&gathered, |e, g| self.experts[e].forward(&serial, g));
        ph.expert_s = t0.elapsed().as_secs_f64();

        // combine: pool barrier; device `dev` owns output rows
        // [dev·tpd, (dev+1)·tpd) and walks only ITS bucket of (expert,
        // row) pairs, whose append order (expert asc, entry asc) fixes
        // the per-row accumulation order — disjoint writes,
        // deterministic sums, each entry touched exactly once.
        let t0 = Instant::now();
        let mut dev_entries: Vec<Vec<(usize, usize)>> = vec![Vec::new(); self.cfg.devices];
        for (e, entries) in per_expert.iter().enumerate() {
            for (r, en) in entries.iter().enumerate() {
                dev_entries[en.token / tokens_per_dev].push((e, r));
            }
        }
        let mut out = Tensor::zeros(&[n_tokens, d]);
        let outs = &outputs;
        let de = &dev_entries;
        pool.for_chunks_mut(out.data_mut(), tokens_per_dev * d, |dev, chunk| {
            let t_lo = dev * tokens_per_dev;
            for &(e, r) in &de[dev] {
                let en = &per_expert[e][r];
                let at = (en.token - t_lo) * d;
                let dst = &mut chunk[at..at + d];
                for (o, s) in dst.iter_mut().zip(outs[e].row(r)) {
                    *o += en.score * s;
                }
            }
        });
        ph.combine_s = t0.elapsed().as_secs_f64();
        (out, ph)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layer() -> HostMoeLayer {
        HostMoeLayer::synth(
            HostMoeConfig {
                n_experts: 8,
                top_k: 2,
                d_model: 16,
                d_ff: 32,
                devices: 4,
            },
            0xD1CE,
        )
    }

    fn tokens(n: usize, d: usize, seed: u64) -> Tensor {
        let mut x = Tensor::zeros(&[n, d]);
        Rng::new(seed).fill_normal(x.data_mut());
        x
    }

    #[test]
    fn step_shape_and_coverage() {
        let l = layer();
        let x = tokens(32, 16, 1);
        let (out, ph) = l.step_timed(&ParPool::new(2), &x);
        assert_eq!(out.shape(), &[32, 16]);
        assert!(out.data().iter().any(|&v| v != 0.0));
        assert!(ph.total_s() >= 0.0);
        // every token got top_k expert contributions
        let (routing, plan) = l.route(&ParPool::new(1), &x);
        assert_eq!(routing.top_k, 2);
        assert_eq!(plan.total_entries(), 32 * 2);
    }

    #[test]
    fn step_is_bit_exact_across_pool_widths() {
        let l = layer();
        let x = tokens(64, 16, 7);
        let serial = l.step(&ParPool::new(1), &x);
        for t in [2usize, 4, 8] {
            assert_eq!(serial, l.step(&ParPool::new(t), &x), "threads={t}");
        }
    }

    #[test]
    fn empty_experts_are_tolerated() {
        // top-1 routing over many experts leaves some experts with no
        // tokens; their gather/FFN blocks are [0, d] and must no-op.
        let l = HostMoeLayer::synth(
            HostMoeConfig {
                n_experts: 16,
                top_k: 1,
                d_model: 8,
                d_ff: 16,
                devices: 2,
            },
            3,
        );
        let x = tokens(4, 8, 11);
        let out = l.step(&ParPool::new(4), &x);
        assert_eq!(out.shape(), &[4, 8]);
    }

    #[test]
    fn non_divisible_devices_and_policy_maps_are_tolerated() {
        // 6 experts over 4 devices: remainder layout 2-2-1-1 instead of
        // the old divisibility panic; and an installed policy map
        // changes only the accounting, never the numerics.
        let l = HostMoeLayer::synth(
            HostMoeConfig {
                n_experts: 6,
                top_k: 2,
                d_model: 8,
                d_ff: 16,
                devices: 4,
            },
            5,
        );
        assert_eq!(l.placement().experts_of(0), vec![0, 1]);
        assert_eq!(l.placement().experts_of(3), vec![5]);
        let x = tokens(8, 8, 3);
        let out = l.step(&ParPool::new(2), &x);
        let scrambled = Placement::from_owner(4, vec![3, 2, 1, 0, 0, 1]);
        let l2 = l.clone().with_placement(scrambled);
        assert_eq!(out, l2.step(&ParPool::new(2), &x), "numerics are placement-invariant");
    }

    #[test]
    fn expert_ffn_matches_manual_small_case() {
        let ffn = ExpertFfn {
            w1t: Tensor::from_vec(&[2, 2], vec![1.0, 0.0, 0.0, 1.0]), // identity
            w2t: Tensor::from_vec(&[2, 2], vec![2.0, 0.0, 0.0, 2.0]), // 2·identity
        };
        let x = Tensor::from_vec(&[1, 2], vec![3.0, -3.0]);
        let y = ffn.forward(&ParPool::new(1), &x);
        // gelu(3) ≈ 2.9964, gelu(-3) ≈ -0.00363; doubled by w2
        assert!((y.data()[0] - 2.0 * 2.9964).abs() < 1e-2, "{:?}", y.data());
        assert!((y.data()[1] + 2.0 * 0.00363).abs() < 1e-2, "{:?}", y.data());
    }
}
