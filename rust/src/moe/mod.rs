//! MoE routing bookkeeping: top-k routing tables extracted from the
//! router probabilities, expert→device placement, and the dispatch plans
//! (who sends which token to which expert) that the engine's all-to-all
//! emulation and the conditional-communication filter operate on.

pub mod host;

use std::cell::Cell;

use crate::netsim::Topology;
use crate::tensor::{ops, Tensor};

/// Expert→device placement: an arbitrary owner map over the routed
/// experts, optionally extended with extra replica devices per expert
/// (DESIGN.md §9, §15).
///
/// [`Placement::new`] builds the contiguous-block baseline (device d
/// owns experts `[d·E/D, (d+1)·E/D)`, with the remainder distributed to
/// the first `E mod D` devices); [`Placement::from_owner`] accepts any
/// single-owner map, which is how the `crate::placement` policies
/// express load-balanced and affinity-aware layouts;
/// [`Placement::with_replicas`] additionally installs extra replica
/// devices per expert (the `crate::placement::replicate` policy's
/// output), so a hot expert's dispatch fan-in splits across its replica
/// holders. A FNV-1a fingerprint of the map is computed once at
/// construction so pricing memos ([`DispatchPlan::cross_bytes`]) can
/// key on the *map*, not just the `(n_experts, devices)` shape; the
/// fingerprint of a replica-free placement is identical to the
/// pre-replication formula, so single-owner memo keys are stable.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Placement {
    /// Total routed experts.
    pub n_experts: usize,
    /// Devices the experts are sharded over.
    pub devices: usize,
    owner: Vec<usize>,
    /// Extra replica devices per expert, each sorted ascending and
    /// excluding the primary owner. Empty inner vecs ⇒ single-owner.
    extra: Vec<Vec<usize>>,
    fingerprint: u64,
}

/// FNV-1a over the owner map (plus the device count so two maps over
/// different device grids never collide trivially). Replica extras fold
/// in only when present, keeping single-owner fingerprints identical to
/// the historical formula.
fn owner_fingerprint(devices: usize, owner: &[usize], extra: &[Vec<usize>]) -> u64 {
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h = OFFSET ^ (devices as u64).wrapping_mul(PRIME);
    for &o in owner {
        h = (h ^ (o as u64 + 1)).wrapping_mul(PRIME);
    }
    for (e, devs) in extra.iter().enumerate() {
        for &d in devs {
            let tag = ((e as u64 + 1) << 32) | (d as u64 + 1);
            h = (h ^ tag).wrapping_mul(PRIME);
        }
    }
    h
}

/// [`Placement::route_of`] over a pre-sorted candidate replica set —
/// shared by the per-expert pricing loops so they resolve each expert's
/// replica list once instead of once per dispatch entry.
fn route_in(all: &[usize], src: usize, topo: Topology, devices: usize) -> usize {
    if all.binary_search(&src).is_ok() {
        return src;
    }
    let src_node = topo.node_of(src, devices);
    let near: Vec<usize> = all
        .iter()
        .copied()
        .filter(|&d| topo.node_of(d, devices) == src_node)
        .collect();
    if near.is_empty() {
        all[src % all.len()]
    } else {
        near[src % near.len()]
    }
}

impl Placement {
    /// Contiguous-block placement. `E` need not divide evenly: the first
    /// `E mod D` devices own one extra expert (the same near-equal split
    /// the worker pool uses for chunk ranges).
    ///
    /// ```
    /// use dice::moe::Placement;
    /// let p = Placement::new(8, 3); // 3-3-2 remainder layout
    /// assert_eq!((p.owner(0), p.owner(3), p.owner(7)), (0, 1, 2));
    /// assert_eq!(p.experts_of(1), vec![3, 4, 5]);
    /// assert_eq!(p.experts_of(2), vec![6, 7]);
    /// ```
    pub fn new(n_experts: usize, devices: usize) -> Placement {
        assert!(devices > 0 && n_experts >= devices, "need at least one expert per device");
        let base = n_experts / devices;
        let rem = n_experts % devices;
        let mut owner = Vec::with_capacity(n_experts);
        for d in 0..devices {
            let cnt = base + usize::from(d < rem);
            owner.resize(owner.len() + cnt, d);
        }
        Placement::from_owner(devices, owner)
    }

    /// Placement from an explicit expert→device map. Panics if any
    /// entry names a device outside `0..devices`.
    pub fn from_owner(devices: usize, owner: Vec<usize>) -> Placement {
        let extra = vec![Vec::new(); owner.len()];
        Placement::with_replicas(devices, owner, extra)
    }

    /// Placement from an owner map plus extra replica devices per
    /// expert. Each `extra[e]` entry is a device that holds a full copy
    /// of expert `e` in addition to the primary `owner[e]`; routing
    /// ([`Placement::route_of`]) then spreads expert `e`'s fan-in across
    /// the whole replica set. Extras are sorted and deduplicated;
    /// entries equal to the primary are dropped. Panics if any device
    /// (owner or extra) falls outside `0..devices`, or if
    /// `extra.len() != owner.len()`.
    pub fn with_replicas(devices: usize, owner: Vec<usize>, extra: Vec<Vec<usize>>) -> Placement {
        assert!(devices > 0, "need at least one device");
        assert!(
            owner.iter().all(|&d| d < devices),
            "owner map names a device >= {devices}"
        );
        assert_eq!(extra.len(), owner.len(), "one replica list per expert");
        let mut extra = extra;
        for (e, devs) in extra.iter_mut().enumerate() {
            assert!(
                devs.iter().all(|&d| d < devices),
                "replica list of expert {e} names a device >= {devices}"
            );
            devs.sort_unstable();
            devs.dedup();
            devs.retain(|&d| d != owner[e]);
        }
        let fingerprint = owner_fingerprint(devices, &owner, &extra);
        Placement {
            n_experts: owner.len(),
            devices,
            owner,
            extra,
            fingerprint,
        }
    }

    /// `self` with `device` added to expert `expert`'s replica set
    /// (no-op if already resident there).
    pub fn add_replica(&self, expert: usize, device: usize) -> Placement {
        let mut extra = self.extra.clone();
        extra[expert].push(device);
        Placement::with_replicas(self.devices, self.owner.clone(), extra)
    }

    /// `self` with every replica extra dropped — the single-owner
    /// placement replica routing is "forced to primaries" against (the
    /// bit-exactness baseline of the `dice exp replicate` gate).
    pub fn primaries_only(&self) -> Placement {
        Placement::from_owner(self.devices, self.owner.clone())
    }

    /// Device that owns `expert`.
    ///
    /// ```
    /// use dice::moe::Placement;
    /// let p = Placement::from_owner(2, vec![1, 0, 1, 0]);
    /// assert_eq!(p.owner(0), 1);
    /// assert_eq!(p.owner(3), 0);
    /// ```
    pub fn owner(&self, expert: usize) -> usize {
        self.owner[expert]
    }

    /// The expert ids a device owns, ascending (no longer necessarily a
    /// contiguous range once a policy map is installed).
    ///
    /// ```
    /// use dice::moe::Placement;
    /// let p = Placement::from_owner(2, vec![1, 0, 1, 0]);
    /// assert_eq!(p.experts_of(0), vec![1, 3]);
    /// assert_eq!(p.experts_of(1), vec![0, 2]);
    /// ```
    pub fn experts_of(&self, device: usize) -> Vec<usize> {
        (0..self.n_experts)
            .filter(|&e| self.owner[e] == device)
            .collect()
    }

    /// The full expert→device map (primary owners only; replica extras
    /// are reported by [`Placement::replicas_of`]).
    pub fn owners(&self) -> &[usize] {
        &self.owner
    }

    /// Every device holding a copy of `expert` — the primary owner plus
    /// any replica extras — sorted ascending.
    ///
    /// ```
    /// use dice::moe::Placement;
    /// let p = Placement::new(4, 4).add_replica(0, 2);
    /// assert_eq!(p.replicas_of(0), vec![0, 2]);
    /// assert_eq!(p.replicas_of(1), vec![1]); // unreplicated expert
    /// assert_eq!(p.owner(0), 0); // the primary is unchanged
    /// ```
    pub fn replicas_of(&self, expert: usize) -> Vec<usize> {
        let mut all = Vec::with_capacity(1 + self.extra[expert].len());
        all.push(self.owner[expert]);
        all.extend_from_slice(&self.extra[expert]);
        all.sort_unstable();
        all
    }

    /// True when any expert carries a replica beyond its primary owner.
    pub fn is_replicated(&self) -> bool {
        self.extra.iter().any(|v| !v.is_empty())
    }

    /// Total expert copies resident across all devices
    /// (`n_experts` when single-owner).
    pub fn total_copies(&self) -> usize {
        self.n_experts + self.extra.iter().map(Vec::len).sum::<usize>()
    }

    /// Expert copies resident per device (primaries + replica extras) —
    /// the count the per-device memory budget constrains.
    pub fn resident_counts(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.devices];
        for &o in &self.owner {
            counts[o] += 1;
        }
        for devs in &self.extra {
            for &d in devs {
                counts[d] += 1;
            }
        }
        counts
    }

    /// The replica of `expert` that a dispatch from `src_device` routes
    /// to, deterministically and topology-aware:
    ///
    /// 1. a copy resident on `src_device` itself wins (zero crossing);
    /// 2. otherwise same-node copies under `topo`, picked as
    ///    `near[src_device % near.len()]` so a hot expert's fan-in
    ///    spreads over its same-node holders;
    /// 3. otherwise `all[src_device % all.len()]` over the full sorted
    ///    replica set.
    ///
    /// For a single-owner placement this is always `owner(expert)`, so
    /// replica routing forced to primaries reproduces the historical
    /// dispatch exactly.
    pub fn route_of(&self, expert: usize, src_device: usize, topo: Topology) -> usize {
        route_in(&self.replicas_of(expert), src_device, topo, self.devices)
    }

    /// FNV-1a fingerprint of the owner map — the memo key
    /// [`DispatchPlan::cross_bytes`] uses to tell placements apart.
    pub fn fingerprint(&self) -> u64 {
        self.fingerprint
    }

    /// Expert copies `self` holds that `other` does not — the
    /// weight-copy count a rebalance (or a replica add) must pay for
    /// (`netsim::CostModel::t_migrate` prices it). For single-owner
    /// placements this is exactly the historical "experts whose owner
    /// changed" count; with replicas, each device newly joining an
    /// expert's replica set is one priced copy (dropping a replica is
    /// free — nothing moves).
    pub fn moved_from(&self, other: &Placement) -> usize {
        let (intra, inter) = self.moved_split(other, Topology::flat());
        intra + inter
    }

    /// [`Placement::moved_from`] split by node boundary under `topo`:
    /// `(intra_node_moves, inter_node_moves)`. Each added copy sources
    /// its weights from the nearest pre-existing replica in `other` —
    /// same-node if one exists (host-bridge fabric), otherwise the NIC
    /// path (`netsim::CostModel::t_migrate_split` prices the latter
    /// strictly above intra-node moves on every shipped profile).
    pub fn moved_split(&self, other: &Placement, topo: Topology) -> (usize, usize) {
        assert_eq!(self.n_experts, other.n_experts, "placement shape mismatch");
        assert_eq!(self.devices, other.devices, "placement device mismatch");
        let (mut intra, mut inter) = (0usize, 0usize);
        for e in 0..self.n_experts {
            let old = other.replicas_of(e);
            for d in self.replicas_of(e) {
                if old.binary_search(&d).is_ok() {
                    continue;
                }
                let node = topo.node_of(d, self.devices);
                if old.iter().any(|&o| topo.node_of(o, self.devices) == node) {
                    intra += 1;
                } else {
                    inter += 1;
                }
            }
        }
        (intra, inter)
    }
}

/// Top-k routing decisions for a flat token range.
///
/// Token indices are *global* (flattened over the whole global batch ×
/// tokens) so that the conditional-communication cache, which must be
/// stable across diffusion steps, can key on them directly.
#[derive(Debug, Clone)]
pub struct RoutingTable {
    /// Tokens routed (global flat count).
    pub n_tokens: usize,
    /// Experts chosen per token.
    pub top_k: usize,
    /// Total experts the router chose from.
    pub n_experts: usize,
    /// [n_tokens * top_k] expert ids, rank-major per token (rank 0 first).
    pub experts: Vec<usize>,
    /// [n_tokens * top_k] router scores aligned with `experts`.
    pub scores: Vec<f32>,
}

impl RoutingTable {
    /// Build from router probabilities [.., E] (any leading shape,
    /// flattened) taking the top-k per token.
    pub fn from_probs(probs: &Tensor, top_k: usize) -> RoutingTable {
        let (n_tokens, e) = probs.rows();
        let mut experts = Vec::with_capacity(n_tokens * top_k);
        let mut scores = Vec::with_capacity(n_tokens * top_k);
        // one scratch index buffer for the whole table: the per-row
        // top-k extraction allocates nothing after the first row.
        let mut scratch = Vec::with_capacity(e);
        for i in 0..n_tokens {
            let row = probs.row(i);
            ops::topk_idx_into(row, top_k, &mut scratch);
            for &idx in scratch.iter() {
                experts.push(idx);
                scores.push(row[idx]);
            }
        }
        RoutingTable {
            n_tokens,
            top_k,
            n_experts: e,
            experts,
            scores,
        }
    }

    /// (rank, expert, score) triples of token `i`, rank order.
    pub fn of_token(&self, i: usize) -> impl Iterator<Item = (usize, usize, f32)> + '_ {
        let k = self.top_k;
        (0..k).map(move |r| (r, self.experts[i * k + r], self.scores[i * k + r]))
    }

    /// Fraction of (token, rank) assignments equal between two tables —
    /// the step-wise routing similarity of Figure 4.
    pub fn similarity(&self, other: &RoutingTable) -> f32 {
        assert_eq!(self.n_tokens, other.n_tokens);
        assert_eq!(self.top_k, other.top_k);
        let same = self
            .experts
            .iter()
            .zip(&other.experts)
            .filter(|(a, b)| a == b)
            .count();
        same as f32 / self.experts.len() as f32
    }
}

/// One entry of a dispatch plan: token row `token` (global flat index)
/// goes to `expert` with router weight `score`; `rank` is its position
/// in the token's top-k (rank 0 = top-1, always kept fresh by
/// conditional communication).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DispatchEntry {
    /// Global flat token index.
    pub token: usize,
    /// Destination expert id.
    pub expert: usize,
    /// Position in the token's top-k (0 = top-1).
    pub rank: usize,
    /// Router score the combine scales by.
    pub score: f32,
    /// device that owns the token (source of the dispatch transfer).
    pub src_device: usize,
}

/// Memo key for [`DispatchPlan::cross_bytes`]: the placement's owner-map
/// fingerprint plus the pricing dims. Keying on the fingerprint (not
/// just `(n_experts, devices)`) keeps the memo correct now that two
/// placements can share a shape but map experts differently
/// (DESIGN.md §9).
type CrossKey = (u64, usize, usize);

/// Memo key for [`DispatchPlan::cross_bytes_split`]: the cross key plus
/// the topology key, since the intra/inter split depends on the node
/// grouping as well as the owner map.
type SplitKey = (u64, u64, usize, usize);

/// A dispatch plan groups entries per expert (the all-to-all payload).
///
/// Plans are immutable after [`DispatchPlan::build`]; the
/// [`DispatchPlan::cross_bytes`] memo relies on that.
#[derive(Debug, Clone, Default)]
pub struct DispatchPlan {
    /// Entries grouped by destination expert.
    pub per_expert: Vec<Vec<DispatchEntry>>,
    /// Last (placement, dims) → crossing-bytes answer.
    cross_memo: Cell<Option<(CrossKey, usize)>>,
    /// Last (placement, topology, dims) → (intra, inter) bytes answer.
    split_memo: Cell<Option<(SplitKey, (usize, usize))>>,
}

impl DispatchPlan {
    /// Build the full (un-throttled) plan from a routing table.
    /// `tokens_per_device` maps global token index -> owning device.
    /// Per-expert entry vectors are sized exactly in a counting pass, so
    /// the build allocates once per expert and never regrows.
    pub fn build(rt: &RoutingTable, tokens_per_device: usize) -> DispatchPlan {
        let mut counts = vec![0usize; rt.n_experts];
        for &e in &rt.experts {
            counts[e] += 1;
        }
        let mut per_expert: Vec<Vec<DispatchEntry>> =
            counts.iter().map(|&c| Vec::with_capacity(c)).collect();
        for i in 0..rt.n_tokens {
            for (rank, expert, score) in rt.of_token(i) {
                per_expert[expert].push(DispatchEntry {
                    token: i,
                    expert,
                    rank,
                    score,
                    src_device: i / tokens_per_device,
                });
            }
        }
        DispatchPlan {
            per_expert,
            cross_memo: Cell::new(None),
            split_memo: Cell::new(None),
        }
    }

    /// Total (token, expert) assignments in the plan.
    pub fn total_entries(&self) -> usize {
        self.per_expert.iter().map(Vec::len).sum()
    }

    /// Bytes this plan moves across devices in ONE direction (dispatch
    /// or combine), counting only entries whose source device holds no
    /// copy of the destination expert — a replica resident on the
    /// source device absorbs the dispatch locally
    /// ([`Placement::route_of`] rule 1), so replicating a hot expert
    /// shrinks this number. For single-owner placements this is exactly
    /// the historical "source differs from owner" count. `elem_bytes`
    /// is the activation element size, `d_model` the token width.
    ///
    /// Memoized per (placement fingerprint, dims): repeat pricing of the
    /// same plan (`CostModel::t_a2a_measured` callers such as `perfprobe
    /// --sim`) scans the entries once instead of once per priced
    /// collective, and a rebalanced owner map with the same shape misses
    /// the memo instead of being served a stale byte count.
    /// The memo cell makes `DispatchPlan` `!Sync` — pool closures must
    /// capture the `per_expert` field, not the plan itself.
    pub fn cross_bytes(&self, placement: &Placement, d_model: usize, elem_bytes: usize) -> usize {
        let key = (placement.fingerprint(), d_model, elem_bytes);
        if let Some((k, v)) = self.cross_memo.get() {
            if k == key {
                return v;
            }
        }
        let mut n = 0usize;
        for (e, entries) in self.per_expert.iter().enumerate() {
            let replicas = placement.replicas_of(e);
            n += entries
                .iter()
                .filter(|en| replicas.binary_search(&en.src_device).is_err())
                .count();
        }
        let bytes = n * d_model * elem_bytes;
        self.cross_memo.set(Some((key, bytes)));
        bytes
    }

    /// [`DispatchPlan::cross_bytes`] split by node boundary under
    /// `topo`: `(intra_node_bytes, inter_node_bytes)`. Each crossing
    /// entry travels to the replica [`Placement::route_of`] picks for
    /// its source device — same-node replicas win, so replicating a hot
    /// expert into a remote node converts NIC bytes into host-bridge
    /// bytes; an entry whose source holds a local copy does not cross
    /// at all. The two components always sum to `cross_bytes` for the
    /// same placement and dims (the local-copy rule is
    /// topology-independent). Memoized like `cross_bytes`, additionally
    /// keyed on the topology ([`Topology::key`]).
    pub fn cross_bytes_split(
        &self,
        placement: &Placement,
        topo: Topology,
        d_model: usize,
        elem_bytes: usize,
    ) -> (usize, usize) {
        let key = (placement.fingerprint(), topo.key(), d_model, elem_bytes);
        if let Some((k, v)) = self.split_memo.get() {
            if k == key {
                return v;
            }
        }
        let devices = placement.devices;
        let (mut intra, mut inter) = (0usize, 0usize);
        for (e, entries) in self.per_expert.iter().enumerate() {
            let replicas = placement.replicas_of(e);
            for en in entries {
                if replicas.binary_search(&en.src_device).is_ok() {
                    continue;
                }
                let dst = route_in(&replicas, en.src_device, topo, devices);
                if topo.node_of(en.src_device, devices) == topo.node_of(dst, devices) {
                    intra += 1;
                } else {
                    inter += 1;
                }
            }
        }
        let v = (intra * d_model * elem_bytes, inter * d_model * elem_bytes);
        self.split_memo.set(Some((key, v)));
        v
    }

    /// Per-expert token loads (imbalance diagnostics; `exp placement`
    /// folds these through a [`Placement`] into per-device loads).
    ///
    /// ```
    /// use dice::moe::{DispatchPlan, RoutingTable};
    /// use dice::tensor::Tensor;
    /// let probs = Tensor::from_vec(&[2, 2], vec![0.9, 0.1, 0.8, 0.2]);
    /// let rt = RoutingTable::from_probs(&probs, 1);
    /// let plan = DispatchPlan::build(&rt, 2);
    /// assert_eq!(plan.loads(), vec![2, 0]); // both tokens pick expert 0
    /// ```
    pub fn loads(&self) -> Vec<usize> {
        self.per_expert.iter().map(Vec::len).collect()
    }

    /// Fold the per-expert loads through a placement into per-DEVICE
    /// expert-compute loads (token-assignments each device executes).
    /// Replicated experts split their load across replica holders under
    /// the flat-topology [`Placement::route_of`] rule; single-owner
    /// placements reduce to "all load on the owner".
    pub fn device_loads(&self, placement: &Placement) -> Vec<usize> {
        self.device_loads_topo(placement, Topology::flat())
    }

    /// [`DispatchPlan::device_loads`] under an explicit topology: the
    /// same fold, but each entry lands on the replica
    /// [`Placement::route_of`] picks for its source device under
    /// `topo` (same-node replicas preferred). Identical to
    /// `device_loads` for single-owner placements on any topology.
    pub fn device_loads_topo(&self, placement: &Placement, topo: Topology) -> Vec<usize> {
        let mut dl = vec![0usize; placement.devices];
        for (e, entries) in self.per_expert.iter().enumerate() {
            let replicas = placement.replicas_of(e);
            if replicas.len() == 1 {
                dl[replicas[0]] += entries.len();
                continue;
            }
            for en in entries {
                dl[route_in(&replicas, en.src_device, topo, placement.devices)] += 1;
            }
        }
        dl
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::{forall, Gen};

    fn probs_of(rows: Vec<Vec<f32>>) -> Tensor {
        let n = rows.len();
        let e = rows[0].len();
        Tensor::from_vec(&[n, e], rows.into_iter().flatten().collect())
    }

    #[test]
    fn placement_blocks() {
        // the divisible case keeps its historical contiguous layout
        let p = Placement::new(8, 4);
        assert_eq!(p.owner(0), 0);
        assert_eq!(p.owner(1), 0);
        assert_eq!(p.owner(7), 3);
        assert_eq!(p.experts_of(2), vec![4, 5]);
    }

    #[test]
    fn placement_distributes_remainder() {
        // 8 experts over 3 devices: first 8 % 3 = 2 devices get an extra
        // expert (3-3-2) instead of the old divisibility panic.
        let p = Placement::new(8, 3);
        assert_eq!(p.experts_of(0), vec![0, 1, 2]);
        assert_eq!(p.experts_of(1), vec![3, 4, 5]);
        assert_eq!(p.experts_of(2), vec![6, 7]);
        let counts: Vec<usize> = (0..3).map(|d| p.experts_of(d).len()).collect();
        assert_eq!(counts.iter().sum::<usize>(), 8);
        assert_eq!(counts.iter().max().unwrap() - counts.iter().min().unwrap(), 1);
    }

    #[test]
    fn placement_owner_map_and_fingerprint() {
        let contig = Placement::new(4, 2);
        let swapped = Placement::from_owner(2, vec![1, 0, 1, 0]);
        assert_eq!(swapped.owner(0), 1);
        assert_eq!(swapped.experts_of(0), vec![1, 3]);
        assert_ne!(contig.fingerprint(), swapped.fingerprint());
        assert_eq!(contig.fingerprint(), Placement::new(4, 2).fingerprint());
        // owners differ at experts 0 (1 vs 0) and 3 (0 vs 1) — two priced
        // copies (the old assertion said 4, miscounting the diff).
        assert_eq!(swapped.moved_from(&contig), 2);
        assert_eq!(swapped.moved_from(&swapped), 0);
    }

    #[test]
    #[should_panic]
    fn placement_rejects_out_of_range_owner() {
        Placement::from_owner(2, vec![0, 2]);
    }

    #[test]
    fn routing_topk_rank_order() {
        let probs = probs_of(vec![vec![0.1, 0.6, 0.3], vec![0.5, 0.2, 0.3]]);
        let rt = RoutingTable::from_probs(&probs, 2);
        let t0: Vec<_> = rt.of_token(0).collect();
        assert_eq!(t0[0], (0, 1, 0.6));
        assert_eq!(t0[1], (1, 2, 0.3));
        let t1: Vec<_> = rt.of_token(1).collect();
        assert_eq!(t1[0].1, 0);
        assert_eq!(t1[1].1, 2);
    }

    #[test]
    fn similarity_bounds() {
        let p1 = probs_of(vec![vec![0.9, 0.1], vec![0.2, 0.8]]);
        let rt1 = RoutingTable::from_probs(&p1, 1);
        assert_eq!(rt1.similarity(&rt1), 1.0);
        let p2 = probs_of(vec![vec![0.1, 0.9], vec![0.8, 0.2]]);
        let rt2 = RoutingTable::from_probs(&p2, 1);
        assert_eq!(rt1.similarity(&rt2), 0.0);
    }

    #[test]
    fn plan_conserves_assignments() {
        // property: every (token, rank) appears exactly once in the plan.
        forall(48, 0xD1CE, |g: &mut Gen| {
            let n_tokens = (g.usize_in(4..40) & !3).max(4); // multiple of 4
            let e = 8;
            let k = g.usize_in(1..4);
            let mut data = Vec::new();
            for _ in 0..n_tokens {
                data.extend(g.prob_row(e));
            }
            let probs = Tensor::from_vec(&[n_tokens, e], data);
            let rt = RoutingTable::from_probs(&probs, k);
            let plan = DispatchPlan::build(&rt, n_tokens / 4);
            assert_eq!(plan.total_entries(), n_tokens * k);
            let mut seen = std::collections::BTreeSet::new();
            for entries in &plan.per_expert {
                for en in entries {
                    assert!(seen.insert((en.token, en.rank)), "dup {:?}", en);
                    assert!(en.score >= 0.0);
                }
            }
            assert_eq!(seen.len(), n_tokens * k);
        });
    }

    #[test]
    fn cross_bytes_zero_on_one_device() {
        let probs = probs_of(vec![vec![0.5, 0.5]; 6]);
        let rt = RoutingTable::from_probs(&probs, 2);
        let plan = DispatchPlan::build(&rt, 6); // all tokens on device 0
        let p = Placement::new(2, 1);
        assert_eq!(plan.cross_bytes(&p, 64, 4), 0);
    }

    #[test]
    fn cross_bytes_memo_is_keyed_on_placement_and_dims() {
        let probs = probs_of(vec![vec![0.6, 0.4]; 8]);
        let rt = RoutingTable::from_probs(&probs, 2);
        let plan = DispatchPlan::build(&rt, 4); // tokens on 2 devices
        let p2 = Placement::new(2, 2);
        let first = plan.cross_bytes(&p2, 16, 4);
        // every token hits both experts; under e0→d0, e1→d1 exactly the
        // 4 opposite-device entries of each expert cross: 8 rows
        assert_eq!(first, 8 * 16 * 4);
        assert_eq!(plan.cross_bytes(&p2, 16, 4), first, "memo hit must agree");
        // different dims must not be served from the memo
        assert_eq!(plan.cross_bytes(&p2, 32, 4), 2 * first);
        assert_eq!(plan.cross_bytes(&p2, 16, 4), first, "re-memoized");
        // a placement with a different owner-map fingerprint recomputes
        // (both experts on device 0: only device-1-sourced rows cross)
        let all_on_0 = Placement::from_owner(2, vec![0, 0]);
        assert_eq!(plan.cross_bytes(&all_on_0, 16, 4), first, "8 rows again, not memo");
        assert_eq!(plan.cross_bytes(&p2, 16, 4), first);
    }

    #[test]
    fn cross_bytes_memo_distinguishes_same_shape_maps() {
        // same (n_experts, devices) shape, different owner maps: the
        // fingerprint key must keep the answers apart. Tokens 0-2 route
        // to expert 0, token 3 to expert 1; tokens sharded 2+2.
        let probs = probs_of(vec![
            vec![1.0, 0.0],
            vec![1.0, 0.0],
            vec![1.0, 0.0],
            vec![0.0, 1.0],
        ]);
        let rt = RoutingTable::from_probs(&probs, 1);
        let plan = DispatchPlan::build(&rt, 2);
        let contig = Placement::new(2, 2); // e0→d0, e1→d1
        let swapped = Placement::from_owner(2, vec![1, 0]);
        // contig: only token 2 (dev1 → e0@dev0) crosses
        assert_eq!(plan.cross_bytes(&contig, 8, 4), 8 * 4);
        // swapped: tokens 0,1 (dev0 → e0@dev1) and 3 (dev1 → e1@dev0)
        assert_eq!(plan.cross_bytes(&swapped, 8, 4), 3 * 8 * 4);
        assert_eq!(plan.cross_bytes(&contig, 8, 4), 8 * 4, "re-memoized");
    }

    #[test]
    fn device_loads_fold_expert_loads_through_the_map() {
        let probs = probs_of(vec![vec![0.7, 0.3]; 4]);
        let rt = RoutingTable::from_probs(&probs, 2);
        let plan = DispatchPlan::build(&rt, 2);
        assert_eq!(plan.loads(), vec![4, 4]);
        assert_eq!(plan.device_loads(&Placement::new(2, 2)), vec![4, 4]);
        assert_eq!(plan.device_loads(&Placement::from_owner(2, vec![0, 0])), vec![8, 0]);
    }

    #[test]
    fn build_preallocates_exact_capacity() {
        let probs = probs_of(vec![vec![0.5, 0.3, 0.2]; 12]);
        let rt = RoutingTable::from_probs(&probs, 2);
        let plan = DispatchPlan::build(&rt, 3);
        for entries in &plan.per_expert {
            assert!(entries.capacity() == entries.len() || entries.is_empty());
        }
    }

    #[test]
    fn cross_bytes_split_sums_and_memoizes() {
        use crate::netsim::Topology;
        // 8 tokens over 4 devices (2 nodes of 2), 4 experts contiguous
        forall(24, 0x70B0, |g: &mut Gen| {
            let e = 4;
            let k = g.usize_in(1..3);
            let mut data = Vec::new();
            for _ in 0..8 {
                data.extend(g.prob_row(e));
            }
            let probs = Tensor::from_vec(&[8, e], data);
            let rt = RoutingTable::from_probs(&probs, k);
            let plan = DispatchPlan::build(&rt, 2);
            let p = Placement::new(e, 4);
            let topo = Topology::multinode(2);
            let (intra, inter) = plan.cross_bytes_split(&p, topo, 16, 2);
            assert_eq!(intra + inter, plan.cross_bytes(&p, 16, 2), "split must sum");
            assert_eq!(plan.cross_bytes_split(&p, topo, 16, 2), (intra, inter), "memo hit");
            // flat topology: every crossing byte is intra-node
            let (fi, fx) = plan.cross_bytes_split(&p, Topology::flat(), 16, 2);
            assert_eq!(fx, 0);
            assert_eq!(fi, plan.cross_bytes(&p, 16, 2));
            // memo keyed on topology: the multinode answer is not stale
            assert_eq!(plan.cross_bytes_split(&p, topo, 16, 2), (intra, inter));
        });
    }

    #[test]
    fn cross_bytes_split_classifies_by_node() {
        use crate::netsim::Topology;
        // tokens 0..4 on devices 0..4 (1 each); all route to expert 0
        let probs = probs_of(vec![vec![1.0, 0.0, 0.0, 0.0]; 4]);
        let rt = RoutingTable::from_probs(&probs, 1);
        let plan = DispatchPlan::build(&rt, 1);
        let p = Placement::new(4, 4); // expert 0 on device 0
        let topo = Topology::multinode(2); // nodes {0,1} and {2,3}
        // dev1 → dev0 crosses intra-node; dev2, dev3 → dev0 cross the NIC
        let (intra, inter) = plan.cross_bytes_split(&p, topo, 10, 2);
        assert_eq!(intra, 10 * 2);
        assert_eq!(inter, 2 * 10 * 2);
    }

    #[test]
    fn moved_split_classifies_by_node() {
        use crate::netsim::Topology;
        let topo = Topology::multinode(2); // 4 devices: nodes {0,1},{2,3}
        let from = Placement::new(4, 4); // e_i → d_i
        // e0: 0→1 intra; e2: 2→3 intra; e1: 1→2 inter; e3 stays
        let to = Placement::from_owner(4, vec![1, 2, 3, 3]);
        assert_eq!(to.moved_split(&from, topo), (2, 1));
        assert_eq!(to.moved_from(&from), 3);
        // flat topology: every move is intra-node
        assert_eq!(to.moved_split(&from, Topology::flat()), (3, 0));
    }

    #[test]
    fn cross_bytes_counts_remote_only() {
        // 2 tokens on devices 0/1; 2 experts owned by devices 0/1.
        let probs = probs_of(vec![vec![1.0, 0.0], vec![1.0, 0.0]]);
        let rt = RoutingTable::from_probs(&probs, 1);
        let plan = DispatchPlan::build(&rt, 1);
        let p = Placement::new(2, 2);
        // token0 (dev0) -> e0 (dev0): local. token1 (dev1) -> e0 (dev0): remote.
        assert_eq!(plan.cross_bytes(&p, 10, 2), 10 * 2);
    }

    #[test]
    fn replicas_normalize_and_fingerprint() {
        // unsorted + duplicated + primary-containing extras normalize
        let p = Placement::with_replicas(4, vec![0, 1, 2, 3], vec![
            vec![2, 2, 0, 2],
            Vec::new(),
            Vec::new(),
            Vec::new(),
        ]);
        assert_eq!(p.replicas_of(0), vec![0, 2]);
        assert_eq!(p.replicas_of(1), vec![1]);
        assert!(p.is_replicated());
        assert_eq!(p.total_copies(), 5);
        assert_eq!(p.resident_counts(), vec![1, 1, 2, 1]);
        // replica-free with_replicas is bit-identical to from_owner
        let bare = Placement::with_replicas(4, vec![0, 1, 2, 3], vec![Vec::new(); 4]);
        assert_eq!(bare, Placement::new(4, 4));
        assert_eq!(bare.fingerprint(), Placement::new(4, 4).fingerprint());
        assert!(!bare.is_replicated());
        // adding a replica changes the fingerprint (memo safety) and
        // primaries_only strips it back to the original
        assert_ne!(p.fingerprint(), bare.fingerprint());
        assert_eq!(p.primaries_only(), bare);
        assert_eq!(p.add_replica(0, 2), p, "re-adding a resident copy is a no-op");
    }

    #[test]
    #[should_panic]
    fn replicas_reject_out_of_range_device() {
        Placement::with_replicas(2, vec![0, 1], vec![vec![2], Vec::new()]);
    }

    #[test]
    #[should_panic]
    fn replicas_reject_shape_mismatch() {
        Placement::with_replicas(2, vec![0, 1], vec![Vec::new()]);
    }

    #[test]
    fn route_of_prefers_local_then_same_node() {
        use crate::netsim::Topology;
        let topo = Topology::multinode(2); // nodes {0,1}, {2,3}
        let single = Placement::new(4, 4);
        for src in 0..4 {
            assert_eq!(single.route_of(0, src, topo), 0, "single-owner routes to primary");
            assert_eq!(single.route_of(0, src, Topology::flat()), 0);
        }
        let p = single.add_replica(0, 2); // copies on {0, 2}
        assert_eq!(p.route_of(0, 0, topo), 0, "resident copy wins");
        assert_eq!(p.route_of(0, 2, topo), 2, "resident copy wins");
        assert_eq!(p.route_of(0, 1, topo), 0, "same-node copy preferred");
        assert_eq!(p.route_of(0, 3, topo), 2, "same-node copy preferred");
        // flat topology: everyone is same-node, spread by src index
        assert_eq!(p.route_of(0, 1, Topology::flat()), 2); // all[1 % 2]
        assert_eq!(p.route_of(0, 3, Topology::flat()), 2); // all[3 % 2]
    }

    #[test]
    fn replicas_absorb_crossing_and_split_load() {
        use crate::netsim::Topology;
        // tokens 0..4 on devices 0..4 (1 each); all route to expert 0
        let probs = probs_of(vec![vec![1.0, 0.0, 0.0, 0.0]; 4]);
        let rt = RoutingTable::from_probs(&probs, 1);
        let plan = DispatchPlan::build(&rt, 1);
        let single = Placement::new(4, 4);
        let repl = single.add_replica(0, 2);
        // sources 1 and 3 still cross; source 2 now has a local copy
        assert_eq!(plan.cross_bytes(&single, 10, 2), 3 * 10 * 2);
        assert_eq!(plan.cross_bytes(&repl, 10, 2), 2 * 10 * 2);
        // node split: single-owner ships srcs 2,3 over the NIC; the
        // node-1 replica converts both to host-bridge (or local) traffic
        let topo = Topology::multinode(2);
        assert_eq!(plan.cross_bytes_split(&single, topo, 10, 2), (10 * 2, 2 * 10 * 2));
        let (intra, inter) = plan.cross_bytes_split(&repl, topo, 10, 2);
        assert_eq!((intra, inter), (2 * 10 * 2, 0));
        assert_eq!(intra + inter, plan.cross_bytes(&repl, 10, 2), "split sums");
        // load splits across the replica holders (flat routing)
        assert_eq!(plan.device_loads(&single), vec![4, 0, 0, 0]);
        assert_eq!(plan.device_loads(&repl), vec![1, 0, 3, 0]);
        assert_eq!(
            plan.device_loads_topo(&repl, topo),
            vec![2, 0, 2, 0],
            "same-node preference rebalances the fold"
        );
        assert_eq!(
            plan.device_loads_topo(&single, topo),
            plan.device_loads(&single),
            "single-owner loads are topology-invariant"
        );
    }

    #[test]
    fn moved_split_prices_replica_adds_not_drops() {
        use crate::netsim::Topology;
        let topo = Topology::multinode(2); // nodes {0,1}, {2,3}
        let base = Placement::new(4, 4);
        // same-node replica add: one intra-node copy
        assert_eq!(base.add_replica(0, 1).moved_split(&base, topo), (1, 0));
        // cross-node replica add: one NIC copy
        assert_eq!(base.add_replica(0, 3).moved_split(&base, topo), (0, 1));
        // once a node-1 copy exists, a second node-1 device copies intra
        let far = base.add_replica(0, 3);
        assert_eq!(far.add_replica(0, 2).moved_split(&far, topo), (1, 0));
        // dropping a replica moves nothing
        assert_eq!(base.moved_split(&far, topo), (0, 0));
        assert_eq!(base.moved_from(&far), 0);
        assert_eq!(far.moved_from(&base), 1);
    }
}
