//! MoE routing bookkeeping: top-k routing tables extracted from the
//! router probabilities, expert→device placement, and the dispatch plans
//! (who sends which token to which expert) that the engine's all-to-all
//! emulation and the conditional-communication filter operate on.

pub mod host;

use std::cell::Cell;

use crate::tensor::{ops, Tensor};

/// Expert placement: contiguous blocks of experts per device
/// (device d owns experts [d·E/D, (d+1)·E/D)).
#[derive(Debug, Clone, Copy)]
pub struct Placement {
    /// Total routed experts.
    pub n_experts: usize,
    /// Devices the experts are sharded over.
    pub devices: usize,
}

impl Placement {
    /// Contiguous-block placement; panics unless devices divides experts.
    pub fn new(n_experts: usize, devices: usize) -> Placement {
        assert!(n_experts % devices == 0, "experts {n_experts} % devices {devices} != 0");
        Placement { n_experts, devices }
    }
    /// Device that owns `expert`.
    pub fn owner(&self, expert: usize) -> usize {
        expert / (self.n_experts / self.devices)
    }
    /// The expert-id range a device owns.
    pub fn experts_of(&self, device: usize) -> std::ops::Range<usize> {
        let per = self.n_experts / self.devices;
        device * per..(device + 1) * per
    }
}

/// Top-k routing decisions for a flat token range.
///
/// Token indices are *global* (flattened over the whole global batch ×
/// tokens) so that the conditional-communication cache, which must be
/// stable across diffusion steps, can key on them directly.
#[derive(Debug, Clone)]
pub struct RoutingTable {
    /// Tokens routed (global flat count).
    pub n_tokens: usize,
    /// Experts chosen per token.
    pub top_k: usize,
    /// Total experts the router chose from.
    pub n_experts: usize,
    /// [n_tokens * top_k] expert ids, rank-major per token (rank 0 first).
    pub experts: Vec<usize>,
    /// [n_tokens * top_k] router scores aligned with `experts`.
    pub scores: Vec<f32>,
}

impl RoutingTable {
    /// Build from router probabilities [.., E] (any leading shape,
    /// flattened) taking the top-k per token.
    pub fn from_probs(probs: &Tensor, top_k: usize) -> RoutingTable {
        let (n_tokens, e) = probs.rows();
        let mut experts = Vec::with_capacity(n_tokens * top_k);
        let mut scores = Vec::with_capacity(n_tokens * top_k);
        // one scratch index buffer for the whole table: the per-row
        // top-k extraction allocates nothing after the first row.
        let mut scratch = Vec::with_capacity(e);
        for i in 0..n_tokens {
            let row = probs.row(i);
            ops::topk_idx_into(row, top_k, &mut scratch);
            for &idx in scratch.iter() {
                experts.push(idx);
                scores.push(row[idx]);
            }
        }
        RoutingTable {
            n_tokens,
            top_k,
            n_experts: e,
            experts,
            scores,
        }
    }

    /// (rank, expert, score) triples of token `i`, rank order.
    pub fn of_token(&self, i: usize) -> impl Iterator<Item = (usize, usize, f32)> + '_ {
        let k = self.top_k;
        (0..k).map(move |r| (r, self.experts[i * k + r], self.scores[i * k + r]))
    }

    /// Fraction of (token, rank) assignments equal between two tables —
    /// the step-wise routing similarity of Figure 4.
    pub fn similarity(&self, other: &RoutingTable) -> f32 {
        assert_eq!(self.n_tokens, other.n_tokens);
        assert_eq!(self.top_k, other.top_k);
        let same = self
            .experts
            .iter()
            .zip(&other.experts)
            .filter(|(a, b)| a == b)
            .count();
        same as f32 / self.experts.len() as f32
    }
}

/// One entry of a dispatch plan: token row `token` (global flat index)
/// goes to `expert` with router weight `score`; `rank` is its position
/// in the token's top-k (rank 0 = top-1, always kept fresh by
/// conditional communication).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DispatchEntry {
    /// Global flat token index.
    pub token: usize,
    /// Destination expert id.
    pub expert: usize,
    /// Position in the token's top-k (0 = top-1).
    pub rank: usize,
    /// Router score the combine scales by.
    pub score: f32,
    /// device that owns the token (source of the dispatch transfer).
    pub src_device: usize,
}

/// Memo key for [`DispatchPlan::cross_bytes`]: the placement identity
/// plus the pricing dims.
type CrossKey = (usize, usize, usize, usize);

/// A dispatch plan groups entries per expert (the all-to-all payload).
///
/// Plans are immutable after [`DispatchPlan::build`]; the
/// [`DispatchPlan::cross_bytes`] memo relies on that.
#[derive(Debug, Clone, Default)]
pub struct DispatchPlan {
    /// Entries grouped by destination expert.
    pub per_expert: Vec<Vec<DispatchEntry>>,
    /// Last (placement, dims) → crossing-bytes answer.
    cross_memo: Cell<Option<(CrossKey, usize)>>,
}

impl DispatchPlan {
    /// Build the full (un-throttled) plan from a routing table.
    /// `tokens_per_device` maps global token index -> owning device.
    /// Per-expert entry vectors are sized exactly in a counting pass, so
    /// the build allocates once per expert and never regrows.
    pub fn build(rt: &RoutingTable, tokens_per_device: usize) -> DispatchPlan {
        let mut counts = vec![0usize; rt.n_experts];
        for &e in &rt.experts {
            counts[e] += 1;
        }
        let mut per_expert: Vec<Vec<DispatchEntry>> =
            counts.iter().map(|&c| Vec::with_capacity(c)).collect();
        for i in 0..rt.n_tokens {
            for (rank, expert, score) in rt.of_token(i) {
                per_expert[expert].push(DispatchEntry {
                    token: i,
                    expert,
                    rank,
                    score,
                    src_device: i / tokens_per_device,
                });
            }
        }
        DispatchPlan {
            per_expert,
            cross_memo: Cell::new(None),
        }
    }

    /// Total (token, expert) assignments in the plan.
    pub fn total_entries(&self) -> usize {
        self.per_expert.iter().map(Vec::len).sum()
    }

    /// Bytes this plan moves across devices in ONE direction (dispatch
    /// or combine), counting only entries whose source device differs
    /// from the expert's owner. `elem_bytes` is the activation element
    /// size, `d_model` the token width.
    ///
    /// Memoized per (placement, dims): repeat pricing of the same plan
    /// (`CostModel::t_a2a_measured` callers such as `perfprobe --sim`)
    /// scans the entries once instead of once per priced collective.
    /// The memo cell makes `DispatchPlan` `!Sync` — pool closures must
    /// capture the `per_expert` field, not the plan itself.
    pub fn cross_bytes(&self, placement: &Placement, d_model: usize, elem_bytes: usize) -> usize {
        let key = (placement.n_experts, placement.devices, d_model, elem_bytes);
        if let Some((k, v)) = self.cross_memo.get() {
            if k == key {
                return v;
            }
        }
        let mut n = 0usize;
        for (e, entries) in self.per_expert.iter().enumerate() {
            let owner = placement.owner(e);
            n += entries.iter().filter(|en| en.src_device != owner).count();
        }
        let bytes = n * d_model * elem_bytes;
        self.cross_memo.set(Some((key, bytes)));
        bytes
    }

    /// Per-expert token loads (imbalance diagnostics).
    pub fn loads(&self) -> Vec<usize> {
        self.per_expert.iter().map(Vec::len).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::{forall, Gen};

    fn probs_of(rows: Vec<Vec<f32>>) -> Tensor {
        let n = rows.len();
        let e = rows[0].len();
        Tensor::from_vec(&[n, e], rows.into_iter().flatten().collect())
    }

    #[test]
    fn placement_blocks() {
        let p = Placement::new(8, 4);
        assert_eq!(p.owner(0), 0);
        assert_eq!(p.owner(1), 0);
        assert_eq!(p.owner(7), 3);
        assert_eq!(p.experts_of(2), 4..6);
    }

    #[test]
    #[should_panic]
    fn placement_requires_divisibility() {
        Placement::new(8, 3);
    }

    #[test]
    fn routing_topk_rank_order() {
        let probs = probs_of(vec![vec![0.1, 0.6, 0.3], vec![0.5, 0.2, 0.3]]);
        let rt = RoutingTable::from_probs(&probs, 2);
        let t0: Vec<_> = rt.of_token(0).collect();
        assert_eq!(t0[0], (0, 1, 0.6));
        assert_eq!(t0[1], (1, 2, 0.3));
        let t1: Vec<_> = rt.of_token(1).collect();
        assert_eq!(t1[0].1, 0);
        assert_eq!(t1[1].1, 2);
    }

    #[test]
    fn similarity_bounds() {
        let p1 = probs_of(vec![vec![0.9, 0.1], vec![0.2, 0.8]]);
        let rt1 = RoutingTable::from_probs(&p1, 1);
        assert_eq!(rt1.similarity(&rt1), 1.0);
        let p2 = probs_of(vec![vec![0.1, 0.9], vec![0.8, 0.2]]);
        let rt2 = RoutingTable::from_probs(&p2, 1);
        assert_eq!(rt1.similarity(&rt2), 0.0);
    }

    #[test]
    fn plan_conserves_assignments() {
        // property: every (token, rank) appears exactly once in the plan.
        forall(48, 0xD1CE, |g: &mut Gen| {
            let n_tokens = (g.usize_in(4..40) & !3).max(4); // multiple of 4
            let e = 8;
            let k = g.usize_in(1..4);
            let mut data = Vec::new();
            for _ in 0..n_tokens {
                data.extend(g.prob_row(e));
            }
            let probs = Tensor::from_vec(&[n_tokens, e], data);
            let rt = RoutingTable::from_probs(&probs, k);
            let plan = DispatchPlan::build(&rt, n_tokens / 4);
            assert_eq!(plan.total_entries(), n_tokens * k);
            let mut seen = std::collections::BTreeSet::new();
            for entries in &plan.per_expert {
                for en in entries {
                    assert!(seen.insert((en.token, en.rank)), "dup {:?}", en);
                    assert!(en.score >= 0.0);
                }
            }
            assert_eq!(seen.len(), n_tokens * k);
        });
    }

    #[test]
    fn cross_bytes_zero_on_one_device() {
        let probs = probs_of(vec![vec![0.5, 0.5]; 6]);
        let rt = RoutingTable::from_probs(&probs, 2);
        let plan = DispatchPlan::build(&rt, 6); // all tokens on device 0
        let p = Placement::new(2, 1);
        assert_eq!(plan.cross_bytes(&p, 64, 4), 0);
    }

    #[test]
    fn cross_bytes_memo_is_keyed_on_placement_and_dims() {
        let probs = probs_of(vec![vec![0.6, 0.4]; 8]);
        let rt = RoutingTable::from_probs(&probs, 2);
        let plan = DispatchPlan::build(&rt, 4); // tokens on 2 devices
        let p2 = Placement::new(2, 2);
        let p1 = Placement::new(2, 1);
        let first = plan.cross_bytes(&p2, 16, 4);
        assert_eq!(plan.cross_bytes(&p2, 16, 4), first, "memo hit must agree");
        // a different placement / dims must not be served from the memo
        assert_eq!(plan.cross_bytes(&p1, 16, 4), 0);
        assert_eq!(plan.cross_bytes(&p2, 32, 4), 2 * first);
        assert_eq!(plan.cross_bytes(&p2, 16, 4), first, "re-memoized");
    }

    #[test]
    fn build_preallocates_exact_capacity() {
        let probs = probs_of(vec![vec![0.5, 0.3, 0.2]; 12]);
        let rt = RoutingTable::from_probs(&probs, 2);
        let plan = DispatchPlan::build(&rt, 3);
        for entries in &plan.per_expert {
            assert!(entries.capacity() == entries.len() || entries.is_empty());
        }
    }

    #[test]
    fn cross_bytes_counts_remote_only() {
        // 2 tokens on devices 0/1; 2 experts owned by devices 0/1.
        let probs = probs_of(vec![vec![1.0, 0.0], vec![1.0, 0.0]]);
        let rt = RoutingTable::from_probs(&probs, 1);
        let plan = DispatchPlan::build(&rt, 1);
        let p = Placement::new(2, 2);
        // token0 (dev0) -> e0 (dev0): local. token1 (dev1) -> e0 (dev0): remote.
        assert_eq!(plan.cross_bytes(&p, 10, 2), 10 * 2);
    }
}
