//! MoE routing bookkeeping: top-k routing tables extracted from the
//! router probabilities, expert→device placement, and the dispatch plans
//! (who sends which token to which expert) that the engine's all-to-all
//! emulation and the conditional-communication filter operate on.

pub mod host;

use std::cell::Cell;

use crate::netsim::Topology;
use crate::tensor::{ops, Tensor};

/// Expert→device placement: an arbitrary owner map over the routed
/// experts (DESIGN.md §9).
///
/// [`Placement::new`] builds the contiguous-block baseline (device d
/// owns experts `[d·E/D, (d+1)·E/D)`, with the remainder distributed to
/// the first `E mod D` devices); [`Placement::from_owner`] accepts any
/// map, which is how the `crate::placement` policies express
/// load-balanced and affinity-aware layouts. A FNV-1a fingerprint of
/// the map is computed once at construction so pricing memos
/// ([`DispatchPlan::cross_bytes`]) can key on the *map*, not just the
/// `(n_experts, devices)` shape.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Placement {
    /// Total routed experts.
    pub n_experts: usize,
    /// Devices the experts are sharded over.
    pub devices: usize,
    owner: Vec<usize>,
    fingerprint: u64,
}

/// FNV-1a over the owner map (plus the device count so two maps over
/// different device grids never collide trivially).
fn owner_fingerprint(devices: usize, owner: &[usize]) -> u64 {
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h = OFFSET ^ (devices as u64).wrapping_mul(PRIME);
    for &o in owner {
        h = (h ^ (o as u64 + 1)).wrapping_mul(PRIME);
    }
    h
}

impl Placement {
    /// Contiguous-block placement. `E` need not divide evenly: the first
    /// `E mod D` devices own one extra expert (the same near-equal split
    /// the worker pool uses for chunk ranges).
    ///
    /// ```
    /// use dice::moe::Placement;
    /// let p = Placement::new(8, 3); // 3-3-2 remainder layout
    /// assert_eq!((p.owner(0), p.owner(3), p.owner(7)), (0, 1, 2));
    /// assert_eq!(p.experts_of(1), vec![3, 4, 5]);
    /// assert_eq!(p.experts_of(2), vec![6, 7]);
    /// ```
    pub fn new(n_experts: usize, devices: usize) -> Placement {
        assert!(devices > 0 && n_experts >= devices, "need at least one expert per device");
        let base = n_experts / devices;
        let rem = n_experts % devices;
        let mut owner = Vec::with_capacity(n_experts);
        for d in 0..devices {
            let cnt = base + usize::from(d < rem);
            owner.resize(owner.len() + cnt, d);
        }
        Placement::from_owner(devices, owner)
    }

    /// Placement from an explicit expert→device map. Panics if any
    /// entry names a device outside `0..devices`.
    pub fn from_owner(devices: usize, owner: Vec<usize>) -> Placement {
        assert!(devices > 0, "need at least one device");
        assert!(
            owner.iter().all(|&d| d < devices),
            "owner map names a device >= {devices}"
        );
        let fingerprint = owner_fingerprint(devices, &owner);
        Placement {
            n_experts: owner.len(),
            devices,
            owner,
            fingerprint,
        }
    }

    /// Device that owns `expert`.
    ///
    /// ```
    /// use dice::moe::Placement;
    /// let p = Placement::from_owner(2, vec![1, 0, 1, 0]);
    /// assert_eq!(p.owner(0), 1);
    /// assert_eq!(p.owner(3), 0);
    /// ```
    pub fn owner(&self, expert: usize) -> usize {
        self.owner[expert]
    }

    /// The expert ids a device owns, ascending (no longer necessarily a
    /// contiguous range once a policy map is installed).
    ///
    /// ```
    /// use dice::moe::Placement;
    /// let p = Placement::from_owner(2, vec![1, 0, 1, 0]);
    /// assert_eq!(p.experts_of(0), vec![1, 3]);
    /// assert_eq!(p.experts_of(1), vec![0, 2]);
    /// ```
    pub fn experts_of(&self, device: usize) -> Vec<usize> {
        (0..self.n_experts)
            .filter(|&e| self.owner[e] == device)
            .collect()
    }

    /// The full expert→device map.
    pub fn owners(&self) -> &[usize] {
        &self.owner
    }

    /// FNV-1a fingerprint of the owner map — the memo key
    /// [`DispatchPlan::cross_bytes`] uses to tell placements apart.
    pub fn fingerprint(&self) -> u64 {
        self.fingerprint
    }

    /// Experts whose owner differs between `self` and `other` — the
    /// weight-migration count a rebalance must pay for
    /// (`netsim::CostModel::t_migrate` prices it).
    pub fn moved_from(&self, other: &Placement) -> usize {
        assert_eq!(self.n_experts, other.n_experts, "placement shape mismatch");
        self.owner
            .iter()
            .zip(&other.owner)
            .filter(|(a, b)| a != b)
            .count()
    }

    /// [`Placement::moved_from`] split by node boundary under `topo`:
    /// `(intra_node_moves, inter_node_moves)`. Cross-node moves travel
    /// the NIC path (`netsim::CostModel::t_migrate_split` prices them
    /// strictly above intra-node moves on every shipped profile).
    pub fn moved_split(&self, other: &Placement, topo: Topology) -> (usize, usize) {
        assert_eq!(self.n_experts, other.n_experts, "placement shape mismatch");
        assert_eq!(self.devices, other.devices, "placement device mismatch");
        let (mut intra, mut inter) = (0usize, 0usize);
        for (&a, &b) in self.owner.iter().zip(&other.owner) {
            if a == b {
                continue;
            }
            if topo.node_of(a, self.devices) == topo.node_of(b, self.devices) {
                intra += 1;
            } else {
                inter += 1;
            }
        }
        (intra, inter)
    }
}

/// Top-k routing decisions for a flat token range.
///
/// Token indices are *global* (flattened over the whole global batch ×
/// tokens) so that the conditional-communication cache, which must be
/// stable across diffusion steps, can key on them directly.
#[derive(Debug, Clone)]
pub struct RoutingTable {
    /// Tokens routed (global flat count).
    pub n_tokens: usize,
    /// Experts chosen per token.
    pub top_k: usize,
    /// Total experts the router chose from.
    pub n_experts: usize,
    /// [n_tokens * top_k] expert ids, rank-major per token (rank 0 first).
    pub experts: Vec<usize>,
    /// [n_tokens * top_k] router scores aligned with `experts`.
    pub scores: Vec<f32>,
}

impl RoutingTable {
    /// Build from router probabilities [.., E] (any leading shape,
    /// flattened) taking the top-k per token.
    pub fn from_probs(probs: &Tensor, top_k: usize) -> RoutingTable {
        let (n_tokens, e) = probs.rows();
        let mut experts = Vec::with_capacity(n_tokens * top_k);
        let mut scores = Vec::with_capacity(n_tokens * top_k);
        // one scratch index buffer for the whole table: the per-row
        // top-k extraction allocates nothing after the first row.
        let mut scratch = Vec::with_capacity(e);
        for i in 0..n_tokens {
            let row = probs.row(i);
            ops::topk_idx_into(row, top_k, &mut scratch);
            for &idx in scratch.iter() {
                experts.push(idx);
                scores.push(row[idx]);
            }
        }
        RoutingTable {
            n_tokens,
            top_k,
            n_experts: e,
            experts,
            scores,
        }
    }

    /// (rank, expert, score) triples of token `i`, rank order.
    pub fn of_token(&self, i: usize) -> impl Iterator<Item = (usize, usize, f32)> + '_ {
        let k = self.top_k;
        (0..k).map(move |r| (r, self.experts[i * k + r], self.scores[i * k + r]))
    }

    /// Fraction of (token, rank) assignments equal between two tables —
    /// the step-wise routing similarity of Figure 4.
    pub fn similarity(&self, other: &RoutingTable) -> f32 {
        assert_eq!(self.n_tokens, other.n_tokens);
        assert_eq!(self.top_k, other.top_k);
        let same = self
            .experts
            .iter()
            .zip(&other.experts)
            .filter(|(a, b)| a == b)
            .count();
        same as f32 / self.experts.len() as f32
    }
}

/// One entry of a dispatch plan: token row `token` (global flat index)
/// goes to `expert` with router weight `score`; `rank` is its position
/// in the token's top-k (rank 0 = top-1, always kept fresh by
/// conditional communication).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DispatchEntry {
    /// Global flat token index.
    pub token: usize,
    /// Destination expert id.
    pub expert: usize,
    /// Position in the token's top-k (0 = top-1).
    pub rank: usize,
    /// Router score the combine scales by.
    pub score: f32,
    /// device that owns the token (source of the dispatch transfer).
    pub src_device: usize,
}

/// Memo key for [`DispatchPlan::cross_bytes`]: the placement's owner-map
/// fingerprint plus the pricing dims. Keying on the fingerprint (not
/// just `(n_experts, devices)`) keeps the memo correct now that two
/// placements can share a shape but map experts differently
/// (DESIGN.md §9).
type CrossKey = (u64, usize, usize);

/// Memo key for [`DispatchPlan::cross_bytes_split`]: the cross key plus
/// the topology key, since the intra/inter split depends on the node
/// grouping as well as the owner map.
type SplitKey = (u64, u64, usize, usize);

/// A dispatch plan groups entries per expert (the all-to-all payload).
///
/// Plans are immutable after [`DispatchPlan::build`]; the
/// [`DispatchPlan::cross_bytes`] memo relies on that.
#[derive(Debug, Clone, Default)]
pub struct DispatchPlan {
    /// Entries grouped by destination expert.
    pub per_expert: Vec<Vec<DispatchEntry>>,
    /// Last (placement, dims) → crossing-bytes answer.
    cross_memo: Cell<Option<(CrossKey, usize)>>,
    /// Last (placement, topology, dims) → (intra, inter) bytes answer.
    split_memo: Cell<Option<(SplitKey, (usize, usize))>>,
}

impl DispatchPlan {
    /// Build the full (un-throttled) plan from a routing table.
    /// `tokens_per_device` maps global token index -> owning device.
    /// Per-expert entry vectors are sized exactly in a counting pass, so
    /// the build allocates once per expert and never regrows.
    pub fn build(rt: &RoutingTable, tokens_per_device: usize) -> DispatchPlan {
        let mut counts = vec![0usize; rt.n_experts];
        for &e in &rt.experts {
            counts[e] += 1;
        }
        let mut per_expert: Vec<Vec<DispatchEntry>> =
            counts.iter().map(|&c| Vec::with_capacity(c)).collect();
        for i in 0..rt.n_tokens {
            for (rank, expert, score) in rt.of_token(i) {
                per_expert[expert].push(DispatchEntry {
                    token: i,
                    expert,
                    rank,
                    score,
                    src_device: i / tokens_per_device,
                });
            }
        }
        DispatchPlan {
            per_expert,
            cross_memo: Cell::new(None),
            split_memo: Cell::new(None),
        }
    }

    /// Total (token, expert) assignments in the plan.
    pub fn total_entries(&self) -> usize {
        self.per_expert.iter().map(Vec::len).sum()
    }

    /// Bytes this plan moves across devices in ONE direction (dispatch
    /// or combine), counting only entries whose source device differs
    /// from the expert's owner. `elem_bytes` is the activation element
    /// size, `d_model` the token width.
    ///
    /// Memoized per (placement fingerprint, dims): repeat pricing of the
    /// same plan (`CostModel::t_a2a_measured` callers such as `perfprobe
    /// --sim`) scans the entries once instead of once per priced
    /// collective, and a rebalanced owner map with the same shape misses
    /// the memo instead of being served a stale byte count.
    /// The memo cell makes `DispatchPlan` `!Sync` — pool closures must
    /// capture the `per_expert` field, not the plan itself.
    pub fn cross_bytes(&self, placement: &Placement, d_model: usize, elem_bytes: usize) -> usize {
        let key = (placement.fingerprint(), d_model, elem_bytes);
        if let Some((k, v)) = self.cross_memo.get() {
            if k == key {
                return v;
            }
        }
        let mut n = 0usize;
        for (e, entries) in self.per_expert.iter().enumerate() {
            let owner = placement.owner(e);
            n += entries.iter().filter(|en| en.src_device != owner).count();
        }
        let bytes = n * d_model * elem_bytes;
        self.cross_memo.set(Some((key, bytes)));
        bytes
    }

    /// [`DispatchPlan::cross_bytes`] split by node boundary under
    /// `topo`: `(intra_node_bytes, inter_node_bytes)`. A crossing entry
    /// whose source device and owning device share a node is intra-node
    /// traffic (host-bridge fabric); the rest crosses the NIC. The two
    /// components always sum to `cross_bytes` for the same placement and
    /// dims. Memoized like `cross_bytes`, additionally keyed on the
    /// topology ([`Topology::key`]).
    pub fn cross_bytes_split(
        &self,
        placement: &Placement,
        topo: Topology,
        d_model: usize,
        elem_bytes: usize,
    ) -> (usize, usize) {
        let key = (placement.fingerprint(), topo.key(), d_model, elem_bytes);
        if let Some((k, v)) = self.split_memo.get() {
            if k == key {
                return v;
            }
        }
        let devices = placement.devices;
        let (mut intra, mut inter) = (0usize, 0usize);
        for (e, entries) in self.per_expert.iter().enumerate() {
            let owner = placement.owner(e);
            let owner_node = topo.node_of(owner, devices);
            for en in entries {
                if en.src_device == owner {
                    continue;
                }
                if topo.node_of(en.src_device, devices) == owner_node {
                    intra += 1;
                } else {
                    inter += 1;
                }
            }
        }
        let v = (intra * d_model * elem_bytes, inter * d_model * elem_bytes);
        self.split_memo.set(Some((key, v)));
        v
    }

    /// Per-expert token loads (imbalance diagnostics; `exp placement`
    /// folds these through a [`Placement`] into per-device loads).
    ///
    /// ```
    /// use dice::moe::{DispatchPlan, RoutingTable};
    /// use dice::tensor::Tensor;
    /// let probs = Tensor::from_vec(&[2, 2], vec![0.9, 0.1, 0.8, 0.2]);
    /// let rt = RoutingTable::from_probs(&probs, 1);
    /// let plan = DispatchPlan::build(&rt, 2);
    /// assert_eq!(plan.loads(), vec![2, 0]); // both tokens pick expert 0
    /// ```
    pub fn loads(&self) -> Vec<usize> {
        self.per_expert.iter().map(Vec::len).collect()
    }

    /// Fold the per-expert loads through a placement into per-DEVICE
    /// expert-compute loads (token-assignments each device executes).
    pub fn device_loads(&self, placement: &Placement) -> Vec<usize> {
        let mut dl = vec![0usize; placement.devices];
        for (e, entries) in self.per_expert.iter().enumerate() {
            dl[placement.owner(e)] += entries.len();
        }
        dl
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::{forall, Gen};

    fn probs_of(rows: Vec<Vec<f32>>) -> Tensor {
        let n = rows.len();
        let e = rows[0].len();
        Tensor::from_vec(&[n, e], rows.into_iter().flatten().collect())
    }

    #[test]
    fn placement_blocks() {
        // the divisible case keeps its historical contiguous layout
        let p = Placement::new(8, 4);
        assert_eq!(p.owner(0), 0);
        assert_eq!(p.owner(1), 0);
        assert_eq!(p.owner(7), 3);
        assert_eq!(p.experts_of(2), vec![4, 5]);
    }

    #[test]
    fn placement_distributes_remainder() {
        // 8 experts over 3 devices: first 8 % 3 = 2 devices get an extra
        // expert (3-3-2) instead of the old divisibility panic.
        let p = Placement::new(8, 3);
        assert_eq!(p.experts_of(0), vec![0, 1, 2]);
        assert_eq!(p.experts_of(1), vec![3, 4, 5]);
        assert_eq!(p.experts_of(2), vec![6, 7]);
        let counts: Vec<usize> = (0..3).map(|d| p.experts_of(d).len()).collect();
        assert_eq!(counts.iter().sum::<usize>(), 8);
        assert_eq!(counts.iter().max().unwrap() - counts.iter().min().unwrap(), 1);
    }

    #[test]
    fn placement_owner_map_and_fingerprint() {
        let contig = Placement::new(4, 2);
        let swapped = Placement::from_owner(2, vec![1, 0, 1, 0]);
        assert_eq!(swapped.owner(0), 1);
        assert_eq!(swapped.experts_of(0), vec![1, 3]);
        assert_ne!(contig.fingerprint(), swapped.fingerprint());
        assert_eq!(contig.fingerprint(), Placement::new(4, 2).fingerprint());
        assert_eq!(swapped.moved_from(&contig), 4);
        assert_eq!(swapped.moved_from(&swapped), 0);
    }

    #[test]
    #[should_panic]
    fn placement_rejects_out_of_range_owner() {
        Placement::from_owner(2, vec![0, 2]);
    }

    #[test]
    fn routing_topk_rank_order() {
        let probs = probs_of(vec![vec![0.1, 0.6, 0.3], vec![0.5, 0.2, 0.3]]);
        let rt = RoutingTable::from_probs(&probs, 2);
        let t0: Vec<_> = rt.of_token(0).collect();
        assert_eq!(t0[0], (0, 1, 0.6));
        assert_eq!(t0[1], (1, 2, 0.3));
        let t1: Vec<_> = rt.of_token(1).collect();
        assert_eq!(t1[0].1, 0);
        assert_eq!(t1[1].1, 2);
    }

    #[test]
    fn similarity_bounds() {
        let p1 = probs_of(vec![vec![0.9, 0.1], vec![0.2, 0.8]]);
        let rt1 = RoutingTable::from_probs(&p1, 1);
        assert_eq!(rt1.similarity(&rt1), 1.0);
        let p2 = probs_of(vec![vec![0.1, 0.9], vec![0.8, 0.2]]);
        let rt2 = RoutingTable::from_probs(&p2, 1);
        assert_eq!(rt1.similarity(&rt2), 0.0);
    }

    #[test]
    fn plan_conserves_assignments() {
        // property: every (token, rank) appears exactly once in the plan.
        forall(48, 0xD1CE, |g: &mut Gen| {
            let n_tokens = (g.usize_in(4..40) & !3).max(4); // multiple of 4
            let e = 8;
            let k = g.usize_in(1..4);
            let mut data = Vec::new();
            for _ in 0..n_tokens {
                data.extend(g.prob_row(e));
            }
            let probs = Tensor::from_vec(&[n_tokens, e], data);
            let rt = RoutingTable::from_probs(&probs, k);
            let plan = DispatchPlan::build(&rt, n_tokens / 4);
            assert_eq!(plan.total_entries(), n_tokens * k);
            let mut seen = std::collections::BTreeSet::new();
            for entries in &plan.per_expert {
                for en in entries {
                    assert!(seen.insert((en.token, en.rank)), "dup {:?}", en);
                    assert!(en.score >= 0.0);
                }
            }
            assert_eq!(seen.len(), n_tokens * k);
        });
    }

    #[test]
    fn cross_bytes_zero_on_one_device() {
        let probs = probs_of(vec![vec![0.5, 0.5]; 6]);
        let rt = RoutingTable::from_probs(&probs, 2);
        let plan = DispatchPlan::build(&rt, 6); // all tokens on device 0
        let p = Placement::new(2, 1);
        assert_eq!(plan.cross_bytes(&p, 64, 4), 0);
    }

    #[test]
    fn cross_bytes_memo_is_keyed_on_placement_and_dims() {
        let probs = probs_of(vec![vec![0.6, 0.4]; 8]);
        let rt = RoutingTable::from_probs(&probs, 2);
        let plan = DispatchPlan::build(&rt, 4); // tokens on 2 devices
        let p2 = Placement::new(2, 2);
        let first = plan.cross_bytes(&p2, 16, 4);
        // every token hits both experts; under e0→d0, e1→d1 exactly the
        // 4 opposite-device entries of each expert cross: 8 rows
        assert_eq!(first, 8 * 16 * 4);
        assert_eq!(plan.cross_bytes(&p2, 16, 4), first, "memo hit must agree");
        // different dims must not be served from the memo
        assert_eq!(plan.cross_bytes(&p2, 32, 4), 2 * first);
        assert_eq!(plan.cross_bytes(&p2, 16, 4), first, "re-memoized");
        // a placement with a different owner-map fingerprint recomputes
        // (both experts on device 0: only device-1-sourced rows cross)
        let all_on_0 = Placement::from_owner(2, vec![0, 0]);
        assert_eq!(plan.cross_bytes(&all_on_0, 16, 4), first, "8 rows again, not memo");
        assert_eq!(plan.cross_bytes(&p2, 16, 4), first);
    }

    #[test]
    fn cross_bytes_memo_distinguishes_same_shape_maps() {
        // same (n_experts, devices) shape, different owner maps: the
        // fingerprint key must keep the answers apart. Tokens 0-2 route
        // to expert 0, token 3 to expert 1; tokens sharded 2+2.
        let probs = probs_of(vec![
            vec![1.0, 0.0],
            vec![1.0, 0.0],
            vec![1.0, 0.0],
            vec![0.0, 1.0],
        ]);
        let rt = RoutingTable::from_probs(&probs, 1);
        let plan = DispatchPlan::build(&rt, 2);
        let contig = Placement::new(2, 2); // e0→d0, e1→d1
        let swapped = Placement::from_owner(2, vec![1, 0]);
        // contig: only token 2 (dev1 → e0@dev0) crosses
        assert_eq!(plan.cross_bytes(&contig, 8, 4), 8 * 4);
        // swapped: tokens 0,1 (dev0 → e0@dev1) and 3 (dev1 → e1@dev0)
        assert_eq!(plan.cross_bytes(&swapped, 8, 4), 3 * 8 * 4);
        assert_eq!(plan.cross_bytes(&contig, 8, 4), 8 * 4, "re-memoized");
    }

    #[test]
    fn device_loads_fold_expert_loads_through_the_map() {
        let probs = probs_of(vec![vec![0.7, 0.3]; 4]);
        let rt = RoutingTable::from_probs(&probs, 2);
        let plan = DispatchPlan::build(&rt, 2);
        assert_eq!(plan.loads(), vec![4, 4]);
        assert_eq!(plan.device_loads(&Placement::new(2, 2)), vec![4, 4]);
        assert_eq!(plan.device_loads(&Placement::from_owner(2, vec![0, 0])), vec![8, 0]);
    }

    #[test]
    fn build_preallocates_exact_capacity() {
        let probs = probs_of(vec![vec![0.5, 0.3, 0.2]; 12]);
        let rt = RoutingTable::from_probs(&probs, 2);
        let plan = DispatchPlan::build(&rt, 3);
        for entries in &plan.per_expert {
            assert!(entries.capacity() == entries.len() || entries.is_empty());
        }
    }

    #[test]
    fn cross_bytes_split_sums_and_memoizes() {
        use crate::netsim::Topology;
        // 8 tokens over 4 devices (2 nodes of 2), 4 experts contiguous
        forall(24, 0x70B0, |g: &mut Gen| {
            let e = 4;
            let k = g.usize_in(1..3);
            let mut data = Vec::new();
            for _ in 0..8 {
                data.extend(g.prob_row(e));
            }
            let probs = Tensor::from_vec(&[8, e], data);
            let rt = RoutingTable::from_probs(&probs, k);
            let plan = DispatchPlan::build(&rt, 2);
            let p = Placement::new(e, 4);
            let topo = Topology::multinode(2);
            let (intra, inter) = plan.cross_bytes_split(&p, topo, 16, 2);
            assert_eq!(intra + inter, plan.cross_bytes(&p, 16, 2), "split must sum");
            assert_eq!(plan.cross_bytes_split(&p, topo, 16, 2), (intra, inter), "memo hit");
            // flat topology: every crossing byte is intra-node
            let (fi, fx) = plan.cross_bytes_split(&p, Topology::flat(), 16, 2);
            assert_eq!(fx, 0);
            assert_eq!(fi, plan.cross_bytes(&p, 16, 2));
            // memo keyed on topology: the multinode answer is not stale
            assert_eq!(plan.cross_bytes_split(&p, topo, 16, 2), (intra, inter));
        });
    }

    #[test]
    fn cross_bytes_split_classifies_by_node() {
        use crate::netsim::Topology;
        // tokens 0..4 on devices 0..4 (1 each); all route to expert 0
        let probs = probs_of(vec![vec![1.0, 0.0, 0.0, 0.0]; 4]);
        let rt = RoutingTable::from_probs(&probs, 1);
        let plan = DispatchPlan::build(&rt, 1);
        let p = Placement::new(4, 4); // expert 0 on device 0
        let topo = Topology::multinode(2); // nodes {0,1} and {2,3}
        // dev1 → dev0 crosses intra-node; dev2, dev3 → dev0 cross the NIC
        let (intra, inter) = plan.cross_bytes_split(&p, topo, 10, 2);
        assert_eq!(intra, 10 * 2);
        assert_eq!(inter, 2 * 10 * 2);
    }

    #[test]
    fn moved_split_classifies_by_node() {
        use crate::netsim::Topology;
        let topo = Topology::multinode(2); // 4 devices: nodes {0,1},{2,3}
        let from = Placement::new(4, 4); // e_i → d_i
        // e0: 0→1 intra; e2: 2→3 intra; e1: 1→2 inter; e3 stays
        let to = Placement::from_owner(4, vec![1, 2, 3, 3]);
        assert_eq!(to.moved_split(&from, topo), (2, 1));
        assert_eq!(to.moved_from(&from), 3);
        // flat topology: every move is intra-node
        assert_eq!(to.moved_split(&from, Topology::flat()), (3, 0));
    }

    #[test]
    fn cross_bytes_counts_remote_only() {
        // 2 tokens on devices 0/1; 2 experts owned by devices 0/1.
        let probs = probs_of(vec![vec![1.0, 0.0], vec![1.0, 0.0]]);
        let rt = RoutingTable::from_probs(&probs, 1);
        let plan = DispatchPlan::build(&rt, 1);
        let p = Placement::new(2, 2);
        // token0 (dev0) -> e0 (dev0): local. token1 (dev1) -> e0 (dev0): remote.
        assert_eq!(plan.cross_bytes(&p, 10, 2), 10 * 2);
    }
}
