//! Bench harness substrate (`criterion` is unavailable offline).
//!
//! Provides: warmup + timed iterations with mean/p50/p99/stddev, and a
//! markdown table writer used by every `benches/*.rs` driver to print the
//! paper-table reproductions. Results can also be appended as JSON lines
//! for post-processing.

use std::io::Write;
use std::path::Path;
use std::time::Instant;

/// Timing summary of one benchmark case.
#[derive(Debug, Clone)]
pub struct Summary {
    /// Case name.
    pub name: String,
    /// Timed iterations (after warmup).
    pub iters: usize,
    /// Mean seconds per iteration.
    pub mean_s: f64,
    /// Median seconds per iteration.
    pub p50_s: f64,
    /// 95th-percentile seconds per iteration (the serving SLO knee the
    /// server reports; benches track the same tail).
    pub p95_s: f64,
    /// 99th-percentile seconds per iteration.
    pub p99_s: f64,
    /// Standard deviation of the iteration times.
    pub std_s: f64,
}

impl Summary {
    /// Render as one JSON object (a single line, no trailing newline) —
    /// the record format of the `BENCH_*.json` trajectory files.
    pub fn to_json(&self) -> String {
        format!(
            "{{\"name\":\"{}\",\"iters\":{},\"mean_s\":{:.9},\"p50_s\":{:.9},\
             \"p95_s\":{:.9},\"p99_s\":{:.9},\"std_s\":{:.9}}}",
            json_escape(&self.name),
            self.iters,
            self.mean_s,
            self.p50_s,
            self.p95_s,
            self.p99_s,
            self.std_s
        )
    }
}

/// Escape a string for embedding in a JSON literal.
fn json_escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

/// Append summaries to a JSON-lines file (one object per line),
/// creating it if missing — successive runs grow the perf trajectory.
pub fn append_jsonl(path: &Path, rows: &[Summary]) -> std::io::Result<()> {
    let mut f = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(path)?;
    for r in rows {
        writeln!(f, "{}", r.to_json())?;
    }
    Ok(())
}

/// Run `f` with warmup, returning the timing summary.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> Summary {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    summarize(name, &samples)
}

/// Summarise raw samples (used when the workload self-times, e.g.
/// virtual-time simulations).
pub fn summarize(name: &str, samples: &[f64]) -> Summary {
    assert!(!samples.is_empty());
    let mut sorted = samples.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = sorted.iter().sum::<f64>() / sorted.len() as f64;
    let var = sorted.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
        / sorted.len() as f64;
    let pct = |p: f64| sorted[(((p / 100.0) * (sorted.len() - 1) as f64).round()) as usize];
    Summary {
        name: name.to_string(),
        iters: samples.len(),
        mean_s: mean,
        p50_s: pct(50.0),
        p95_s: pct(95.0),
        p99_s: pct(99.0),
        std_s: var.sqrt(),
    }
}

/// Markdown table builder for paper-table reproductions.
pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// Empty table with a title and column headers.
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }
    /// Append a row; panics when the width differs from the headers.
    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells);
        self
    }

    /// Render as aligned markdown.
    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut s = format!("\n### {}\n\n", self.title);
        let line = |cells: &[String], widths: &[usize]| {
            let mut l = String::from("|");
            for i in 0..ncol {
                l.push_str(&format!(" {:<w$} |", cells[i], w = widths[i]));
            }
            l.push('\n');
            l
        };
        s.push_str(&line(&self.headers, &widths));
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        sep.push('\n');
        s.push_str(&sep);
        for r in &self.rows {
            s.push_str(&line(r, &widths));
        }
        s
    }

    /// Print the rendered table to stdout.
    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format seconds adaptively.
pub fn fmt_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.2}s")
    } else if s >= 1e-3 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.1}us", s * 1e6)
    }
}

/// Format bytes adaptively.
pub fn fmt_bytes(b: usize) -> String {
    const G: f64 = 1024.0 * 1024.0 * 1024.0;
    const M: f64 = 1024.0 * 1024.0;
    let f = b as f64;
    if f >= G {
        format!("{:.2}GB", f / G)
    } else if f >= M {
        format!("{:.1}MB", f / M)
    } else {
        format!("{:.1}KB", f / 1024.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_stats() {
        let s = bench("noop", 2, 16, || {
            std::hint::black_box(42);
        });
        assert_eq!(s.iters, 16);
        assert!(s.mean_s >= 0.0 && s.mean_s < 0.1);
        assert!(s.p50_s <= s.p99_s + 1e-12);
    }

    #[test]
    fn summarize_percentiles() {
        let samples: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let s = summarize("x", &samples);
        assert!((s.mean_s - 50.5).abs() < 1e-9);
        assert!((s.p50_s - 51.0).abs() <= 1.0);
        assert!(s.p95_s >= 94.0 && s.p95_s <= s.p99_s);
        assert!(s.p99_s >= 99.0);
    }

    #[test]
    fn to_json_is_parseable_and_escaped() {
        let mut s = summarize("engine \"step\"", &[0.25, 0.5, 0.75]);
        s.iters = 3;
        let j = s.to_json();
        let parsed = crate::config::Json::parse(&j).expect("valid JSON");
        assert_eq!(
            parsed.get("name").and_then(crate::config::Json::as_str),
            Some("engine \"step\"")
        );
        assert_eq!(parsed.get("iters").and_then(crate::config::Json::as_usize), Some(3));
        let mean = parsed.get("mean_s").and_then(crate::config::Json::as_f64).unwrap();
        assert!((mean - 0.5).abs() < 1e-6);
    }

    #[test]
    fn jsonl_appends_across_runs() {
        let path = std::env::temp_dir().join(format!(
            "benchkit_jsonl_{}.json",
            std::process::id()
        ));
        let _ = std::fs::remove_file(&path);
        let s1 = summarize("a", &[0.1]);
        let s2 = summarize("b", &[0.2]);
        append_jsonl(&path, &[s1]).unwrap();
        append_jsonl(&path, &[s2]).unwrap(); // second run appends
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        for l in &lines {
            crate::config::Json::parse(l).expect("each line is one JSON object");
        }
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn table_renders_markdown() {
        let mut t = Table::new("Table 1", &["method", "FID"]);
        t.row(vec!["sync_ep".into(), "5.31".into()]);
        t.row(vec!["dice".into(), "6.11".into()]);
        let md = t.render();
        assert!(md.contains("### Table 1"));
        assert!(md.contains("| sync_ep"));
        assert!(md.lines().filter(|l| l.starts_with('|')).count() == 4);
    }

    #[test]
    #[should_panic]
    fn table_row_width_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn formatters() {
        assert_eq!(fmt_secs(2.5), "2.50s");
        assert_eq!(fmt_secs(0.0025), "2.50ms");
        assert_eq!(fmt_bytes(2 * 1024 * 1024 * 1024), "2.00GB");
        assert_eq!(fmt_bytes(1536), "1.5KB");
    }
}
