//! # DICE — staleness-centric optimizations for parallel diffusion MoE inference
//!
//! A full-system reproduction of the DICE paper as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **L1/L2 (build time)** — `python/compile/` authors the DiT-MoE model and
//!   its Pallas kernels and AOT-lowers every stage to HLO text
//!   (`artifacts/*.hlo.txt`); Python never runs on the request path.
//! * **L3 (this crate)** — the coordinator: expert-parallel engine, the
//!   paper's parallelism strategies (synchronous EP, displaced EP,
//!   interweaved parallelism, DistriFusion), selective synchronization,
//!   conditional communication, residual all-to-all compression
//!   (DESIGN.md §7), the serving stack, and the evaluation harness that
//!   regenerates every table and figure of the paper.
//!
//! The offline crate universe is tiny (the in-tree `xla` stub crate plus
//! `anyhow` / `thiserror` / `once_cell`), so the usual ecosystem pieces —
//! CLI parsing, config, tensors, dense linalg, RNG, metrics, property-test
//! and bench harnesses — are implemented in-tree as substrates (see
//! DESIGN.md §4). The serving stack that fronts the engine is described
//! in DESIGN.md §6.

#![warn(missing_docs)]

pub mod benchkit;
pub mod cli;
pub mod compress;
pub mod config;
pub mod coordinator;
pub mod desim;
pub mod exp;
pub mod linalg;
pub mod metrics;
pub mod moe;
pub mod netsim;
pub mod par;
pub mod quality;
pub mod rng;
pub mod runtime;
pub mod sampler;
pub mod server;
pub mod tensor;
pub mod testkit;
pub mod workload;

/// Repository-relative default artifact directory.
pub const DEFAULT_ARTIFACTS: &str = "artifacts";
