//! # DICE — staleness-centric optimizations for parallel diffusion MoE inference
//!
//! A full-system reproduction of the DICE paper as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **L1/L2 (build time)** — `python/compile/` authors the DiT-MoE model and
//!   its Pallas kernels and AOT-lowers every stage to HLO text
//!   (`artifacts/*.hlo.txt`); Python never runs on the request path.
//! * **L3 (this crate)** — the coordinator: expert-parallel engine, the
//!   paper's parallelism strategies (synchronous EP, displaced EP,
//!   interweaved parallelism, DistriFusion), selective synchronization,
//!   conditional communication, residual all-to-all compression
//!   (DESIGN.md §7), policy-driven expert placement (DESIGN.md §9), the
//!   serving stack, and the evaluation harness that regenerates every
//!   table and figure of the paper.
//!
//! ## Module map
//!
//! The runtime proper is eight modules; everything else is substrate
//! (DESIGN.md §4).
//!
//! * [`coordinator`] — the paper's system contribution: the
//!   real-numerics expert-parallel engine executing Algorithms 1–4 over
//!   the AOT artifacts ([`coordinator::Engine`]), the virtual-time
//!   schedule simulation of the same strategies at the paper's scales
//!   ([`coordinator::simulate`](mod@coordinator::simulate)), the
//!   stale-activation buffer manager
//!   and allocation arena, the conditional-communication filter, the
//!   staleness ledger, the overlapped multi-layer multi-step host
//!   pipeline ([`coordinator::HostPipeline`], DESIGN.md §10–§11) that
//!   executes the displaced/interweaved overlap schedules with live
//!   threads and MEASURED per-(step, layer) staleness ages — the cost
//!   model's overlap claim, run for real — and the selective-sync
//!   tuner ([`coordinator::SyncTuner`], `--sync-layers auto`) that
//!   turns per-layer sensitivity probes into a measured
//!   [`config::SelectiveSync::Schedule`] bitmask. Staleness is data,
//!   time is accounting (DESIGN.md §2).
//! * [`moe`] — routing bookkeeping shared by every execution path:
//!   top-k [`moe::RoutingTable`]s, the expert→device [`moe::Placement`]
//!   map, [`moe::DispatchPlan`] (the all-to-all payload, with memoized
//!   crossing-bytes pricing), and the artifact-free host-numerics MoE
//!   engine step ([`moe::host`]) that the perf gate and determinism
//!   suite drive.
//! * [`placement`] — load/affinity-aware expert placement (DESIGN.md
//!   §9): [`placement::RoutingStats`] observed from routing tables, the
//!   [`placement::PlacementPolicy`] solvers (contiguous / load-balanced
//!   / affinity-aware), and the per-interval [`placement::Rebalancer`]
//!   whose weight migrations `netsim` prices. Selected by
//!   [`config::PlacementKind`] (`--placement`). Memory-budgeted
//!   hot-expert replication (DESIGN.md §15) lives here too:
//!   [`placement::replicate_hot`] fills spare budget slots
//!   (`--memory-budget` / `--replicate`) with copies of the hottest
//!   experts and the per-device [`placement::ExpertCache`] prices every
//!   weight fetch-on-miss over the migration fabric.
//! * [`compress`] — residual all-to-all compression (DESIGN.md §7):
//!   [`compress::ResidualCodec`] implementations (identity / int8 /
//!   top-k) over inter-step activation deltas with error feedback,
//!   transcoding exactly the rows that cross devices. Selected by
//!   [`config::CompressionCodec`] (`--compress`).
//! * [`par`] — the execution runtime (DESIGN.md §8, §10): a scoped
//!   worker pool ([`par::ParPool`]) with static decomposition and
//!   disjoint writes, plus dynamic scheduling
//!   ([`par::ParPool::map_dynamic`]) and a dependency-driven task
//!   runner ([`par::ParPool::run_graph`] over [`par::TaskGraph`]) whose
//!   pre-indexed result slots keep every pool-driven computation
//!   bit-exact for any `--threads` width.
//! * [`netsim`] — the analytic cost model of the paper's testbeds:
//!   α+β collectives under host-bridge contention, FLOP pricing with a
//!   utilisation ramp, codec and migration overheads, and the
//!   byte-accurate memory model ([`netsim::CostModel`]). Prices both
//!   analytic payloads and measured [`moe::DispatchPlan`]s.
//! * [`server`] — the serving stack (DESIGN.md §6): admission control,
//!   multi-bucket dynamic batching, the virtual-time serve loop over a
//!   [`server::BatchExecutor`] (real numerics or cost-model-only),
//!   latency/goodput reporting, and the multi-replica fleet layer
//!   ([`server::fleet`], DESIGN.md §14) — routing, autoscaling, fault
//!   injection and replica-seconds cost accounting.
//! * [`exp`] — experiment drivers, one per paper table/figure plus the
//!   extension studies ([`exp::compress`], [`exp::placement`]); the
//!   `benches/*.rs` binaries are thin wrappers.
//!
//! Substrates: [`cli`] (argument parsing), [`config`] (model/hardware
//! presets, strategy + knob enums, JSON), [`tensor`] / [`linalg`] /
//! [`rng`] (numerics — the hot inner loops run on the
//! runtime-dispatched SIMD micro-kernels of [`linalg::simd`], scalar /
//! portable / AVX2, all bit-exact under the strict-order lane contract
//! of DESIGN.md §12, selected by `--simd` / `DICE_SIMD`),
//! [`desim`] (virtual-time DES), [`metrics`],
//! [`workload`] (arrival processes + scenario presets), [`quality`]
//! (FID/sFID/IS), [`sampler`], [`runtime`] (PJRT artifact runtime),
//! [`benchkit`] and [`testkit`] (bench/property harnesses).
//!
//! The offline crate universe is tiny (the in-tree `xla` stub crate plus
//! `anyhow` / `thiserror` / `once_cell`), so those substrates are
//! implemented in-tree (DESIGN.md §4).

#![warn(missing_docs)]

pub mod benchkit;
pub mod cli;
pub mod compress;
pub mod config;
pub mod coordinator;
pub mod desim;
pub mod exp;
pub mod linalg;
pub mod metrics;
pub mod moe;
pub mod netsim;
pub mod par;
pub mod placement;
pub mod quality;
pub mod rng;
pub mod runtime;
pub mod sampler;
pub mod server;
pub mod tensor;
pub mod testkit;
pub mod workload;

/// Repository-relative default artifact directory.
pub const DEFAULT_ARTIFACTS: &str = "artifacts";
