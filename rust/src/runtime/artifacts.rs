//! Weight staging: mirrors the argument orders fixed by
//! `python/compile/aot.py` and pre-uploads every layer's weight slice as
//! PJRT device buffers so the hot loop never re-uploads parameters.
//!
//! Argument orders (after the activation args):
//!   embed      : patch.w, patch.b, pos
//!   cond       : t1.w, t1.b, t2.w, t2.b, ytable
//!   block_pre  : adaln.w, adaln.b, qkv.w, qkv.b, proj.w, proj.b, router.w
//!   block_post : shared.0.fc1.w, shared.0.fc1.b, shared.0.fc2.w, shared.0.fc2.b
//!   final      : final.adaln.w, final.adaln.b, final.out.w, final.out.b
//!   moe_dense  : stacked w1[E,D,F], b1[E,F], w2[E,F,D], b2[E,D]
//!   dfu_block  : block_pre order + stacked + block_post order
//!   expert_tile: experts.{e}.fc1.w, fc1.b, fc2.w, fc2.b
//!   featnet    : cls.fc1.w, fc1.b, fc2.w, fc2.b
//!   classifier : featnet order + cls.out.w, out.b

use anyhow::Result;

use super::Runtime;
use crate::tensor::{stf::StfFile, Tensor};

/// Device-resident weights, grouped per call site.
pub struct WeightBank {
    /// embed-module weight args.
    pub embed: Vec<xla::PjRtBuffer>,
    /// cond-module weight args.
    pub cond: Vec<xla::PjRtBuffer>,
    /// per layer: block_pre weight args
    pub block_pre: Vec<Vec<xla::PjRtBuffer>>,
    /// per layer: block_post weight args
    pub block_post: Vec<Vec<xla::PjRtBuffer>>,
    /// final-module weight args.
    pub final_: Vec<xla::PjRtBuffer>,
    /// per layer: stacked expert weights (moe_dense / dfu)
    pub stacked: Vec<Vec<xla::PjRtBuffer>>,
    /// per layer, per expert: expert_tile weight args
    pub experts: Vec<Vec<Vec<xla::PjRtBuffer>>>,
    /// feature-net weight args (quality metrics).
    pub featnet: Vec<xla::PjRtBuffer>,
    /// classifier weight args (quality metrics).
    pub classifier: Vec<xla::PjRtBuffer>,
    /// Host copies of router probs scalers etc. kept for byte accounting.
    pub param_bytes: usize,
}

fn up(rt: &Runtime, w: &StfFile, name: &str, bytes: &mut usize) -> Result<xla::PjRtBuffer> {
    let t = w.f32(name)?;
    *bytes += t.byte_size();
    rt.upload(t)
}

/// Stack per-expert tensors [E copies of shape] -> [E, ...shape].
fn stack(rt: &Runtime, w: &StfFile, layer: usize, field: &str, n_experts: usize, bytes: &mut usize) -> Result<xla::PjRtBuffer> {
    let first = w.f32(&format!("blocks.{layer}.experts.0.{field}"))?;
    let mut shape = vec![n_experts];
    shape.extend_from_slice(first.shape());
    let mut data = Vec::with_capacity(first.len() * n_experts);
    for e in 0..n_experts {
        data.extend_from_slice(w.f32(&format!("blocks.{layer}.experts.{e}.{field}"))?.data());
    }
    let t = Tensor::from_vec(&shape, data);
    *bytes += t.byte_size();
    rt.upload(&t)
}

impl WeightBank {
    /// Upload every weight group from an STF file to device buffers
    /// (once per process; the hot loop reuses them every step).
    pub fn stage(rt: &Runtime, w: &StfFile) -> Result<WeightBank> {
        let m = &rt.model;
        let mut bytes = 0usize;
        let u = |n: &str, b: &mut usize| up(rt, w, n, b);

        let embed = ["embed.patch.w", "embed.patch.b", "embed.pos"]
            .iter()
            .map(|n| u(n, &mut bytes))
            .collect::<Result<Vec<_>>>()?;
        let cond = ["cond.t1.w", "cond.t1.b", "cond.t2.w", "cond.t2.b", "cond.ytable"]
            .iter()
            .map(|n| u(n, &mut bytes))
            .collect::<Result<Vec<_>>>()?;
        let final_ = ["final.adaln.w", "final.adaln.b", "final.out.w", "final.out.b"]
            .iter()
            .map(|n| u(n, &mut bytes))
            .collect::<Result<Vec<_>>>()?;
        let featnet = ["cls.fc1.w", "cls.fc1.b", "cls.fc2.w", "cls.fc2.b"]
            .iter()
            .map(|n| u(n, &mut bytes))
            .collect::<Result<Vec<_>>>()?;
        let mut classifier = ["cls.fc1.w", "cls.fc1.b", "cls.fc2.w", "cls.fc2.b", "cls.out.w", "cls.out.b"]
            .iter()
            .map(|n| u(n, &mut bytes))
            .collect::<Result<Vec<_>>>()?;
        // classifier re-uploads the featnet weights; that's fine (tiny).
        let _ = &mut classifier;

        let mut block_pre = Vec::with_capacity(m.n_layers);
        let mut block_post = Vec::with_capacity(m.n_layers);
        let mut stacked = Vec::with_capacity(m.n_layers);
        let mut experts = Vec::with_capacity(m.n_layers);
        for l in 0..m.n_layers {
            let pre = ["adaln.w", "adaln.b", "qkv.w", "qkv.b", "proj.w", "proj.b", "router.w"]
                .iter()
                .map(|f| up(rt, w, &format!("blocks.{l}.{f}"), &mut bytes))
                .collect::<Result<Vec<_>>>()?;
            block_pre.push(pre);
            let post = ["shared.0.fc1.w", "shared.0.fc1.b", "shared.0.fc2.w", "shared.0.fc2.b"]
                .iter()
                .map(|f| up(rt, w, &format!("blocks.{l}.{f}"), &mut bytes))
                .collect::<Result<Vec<_>>>()?;
            block_post.push(post);
            let st = ["fc1.w", "fc1.b", "fc2.w", "fc2.b"]
                .iter()
                .map(|f| stack(rt, w, l, f, m.n_experts, &mut bytes))
                .collect::<Result<Vec<_>>>()?;
            stacked.push(st);
            let mut per_e = Vec::with_capacity(m.n_experts);
            for e in 0..m.n_experts {
                let ws = ["fc1.w", "fc1.b", "fc2.w", "fc2.b"]
                    .iter()
                    .map(|f| up(rt, w, &format!("blocks.{l}.experts.{e}.{f}"), &mut bytes))
                    .collect::<Result<Vec<_>>>()?;
                per_e.push(ws);
            }
            experts.push(per_e);
        }

        Ok(WeightBank {
            embed,
            cond,
            block_pre,
            block_post,
            final_,
            stacked,
            experts,
            featnet,
            classifier,
            param_bytes: bytes,
        })
    }

    /// Borrow a weight group as the `staged` argument slice.
    pub fn refs(group: &[xla::PjRtBuffer]) -> Vec<&xla::PjRtBuffer> {
        group.iter().collect()
    }

    /// dfu_block staged args: pre + stacked + post for a layer.
    pub fn dfu_refs(&self, layer: usize) -> Vec<&xla::PjRtBuffer> {
        let mut v: Vec<&xla::PjRtBuffer> = self.block_pre[layer].iter().collect();
        v.extend(self.stacked[layer].iter());
        v.extend(self.block_post[layer].iter());
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::Path;

    #[test]
    fn stage_all_weights() {
        let dir = Path::new("artifacts");
        if !dir.join("manifest.json").exists() {
            return;
        }
        let rt = Runtime::open(dir).unwrap();
        let w = rt.load_weights().unwrap();
        let bank = WeightBank::stage(&rt, &w).unwrap();
        assert_eq!(bank.block_pre.len(), rt.model.n_layers);
        assert_eq!(bank.experts[0].len(), rt.model.n_experts);
        assert_eq!(bank.block_pre[0].len(), 7);
        assert_eq!(bank.block_post[0].len(), 4);
        assert_eq!(bank.stacked[0].len(), 4);
        assert_eq!(bank.dfu_refs(0).len(), 15);
        // ~1.2M params * 4B, plus the stacked duplicates
        assert!(bank.param_bytes > 4_000_000, "{}", bank.param_bytes);
    }
}
