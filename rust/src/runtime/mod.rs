//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`, produced
//! once at build time by `python/compile/aot.py`) and executes them from
//! the coordinator's hot path. Python never runs here.
//!
//! * [`Runtime`] — PJRT CPU client + manifest + compile cache. HLO *text*
//!   is the interchange format (xla_extension 0.5.1 rejects jax's 64-bit
//!   proto ids; the text parser reassigns them).
//! * [`WeightBank`] — per-layer weight argument lists pre-staged as
//!   device buffers (uploaded once, reused every step).
//!
//! All stage modules were lowered with `return_tuple=True`, so every
//! execution returns one tuple literal which we decompose.

pub mod artifacts;

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::config::{Json, ModelConfig};
use crate::tensor::{stf::StfFile, Tensor};

pub use artifacts::WeightBank;

/// Loaded artifact store + execution cache.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    /// Parsed `manifest.json` (module inventory + export config).
    pub manifest: Json,
    /// Model architecture the artifacts were exported at.
    pub model: ModelConfig,
    /// module name -> compiled executable (compiled lazily, cached).
    exes: RefCell<BTreeMap<String, std::rc::Rc<xla::PjRtLoadedExecutable>>>,
    /// cumulative count of PJRT executions (perf accounting).
    pub exec_count: RefCell<u64>,
}

impl Runtime {
    /// Open an artifact directory (reads `manifest.json`).
    pub fn open(dir: &Path) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("pjrt: {e}"))?;
        let mtext = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("read {}/manifest.json (run `make artifacts`)", dir.display()))?;
        let manifest = Json::parse(&mtext).context("parse manifest.json")?;
        let model = ModelConfig::from_manifest(&manifest)?;
        Ok(Runtime {
            client,
            dir: dir.to_path_buf(),
            manifest,
            model,
            exes: RefCell::new(BTreeMap::new()),
            exec_count: RefCell::new(0),
        })
    }

    /// The underlying PJRT client.
    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    /// The artifact directory this runtime was opened on.
    pub fn artifact_dir(&self) -> &Path {
        &self.dir
    }

    /// Batch buckets the EP-mode modules were exported at.
    pub fn batch_buckets(&self) -> Vec<usize> {
        self.manifest
            .get("ep_batch_buckets")
            .and_then(Json::as_arr)
            .map(|a| a.iter().filter_map(Json::as_usize).collect())
            .unwrap_or_else(|| vec![1, 2, 4, 8, 32])
    }

    /// Smallest exported bucket that fits `n` (serving shape buckets).
    pub fn bucket_for(&self, n: usize) -> Result<usize> {
        self.batch_buckets()
            .into_iter()
            .filter(|&b| b >= n)
            .min()
            .with_context(|| format!("no batch bucket fits {n}"))
    }

    /// Compile (or fetch the cached) executable for a module.
    pub fn executable(&self, name: &str) -> Result<std::rc::Rc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.exes.borrow().get(name) {
            return Ok(e.clone());
        }
        let path = self.dir.join(format!("{name}.hlo.txt"));
        if !path.exists() {
            bail!("artifact {} not found", path.display());
        }
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow::anyhow!("parse {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {name}: {e}"))?;
        let rc = std::rc::Rc::new(exe);
        self.exes.borrow_mut().insert(name.to_string(), rc.clone());
        Ok(rc)
    }

    /// Pre-compile a list of modules (serving cold-start avoidance).
    pub fn warm(&self, names: &[&str]) -> Result<()> {
        for n in names {
            self.executable(n)?;
        }
        Ok(())
    }

    /// Upload a host tensor to the device.
    pub fn upload(&self, t: &Tensor) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(t.data(), t.shape(), None)
            .map_err(|e| anyhow::anyhow!("upload: {e}"))
    }

    /// Execute a module on mixed host-tensor + pre-staged buffer args.
    /// `args` are uploaded fresh; `staged` (e.g. weights) follow them.
    pub fn execute(
        &self,
        name: &str,
        args: &[&Tensor],
        staged: &[&xla::PjRtBuffer],
    ) -> Result<Vec<Tensor>> {
        let exe = self.executable(name)?;
        let mut bufs: Vec<xla::PjRtBuffer> = Vec::with_capacity(args.len());
        for t in args {
            bufs.push(self.upload(t)?);
        }
        let mut all: Vec<&xla::PjRtBuffer> = Vec::with_capacity(args.len() + staged.len());
        all.extend(bufs.iter());
        all.extend(staged.iter().copied());
        let out = exe
            .execute_b(&all)
            .map_err(|e| anyhow::anyhow!("execute {name}: {e}"))?;
        *self.exec_count.borrow_mut() += 1;
        // return_tuple=True => single tuple output
        let lit = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch {name}: {e}"))?;
        let parts = lit
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("untuple {name}: {e}"))?;
        parts.into_iter().map(|l| literal_to_tensor(&l)).collect()
    }

    /// Load the trained weights file.
    pub fn load_weights(&self) -> Result<StfFile> {
        StfFile::load(&self.dir.join("weights.stf"))
    }

    /// Load the metric reference statistics.
    pub fn load_ref_stats(&self) -> Result<StfFile> {
        StfFile::load(&self.dir.join("ref_stats.stf"))
    }

    /// Load the python-oracle golden vectors.
    pub fn load_golden(&self) -> Result<StfFile> {
        StfFile::load(&self.dir.join("golden.stf"))
    }
}

/// Convert an f32 literal (any rank) to a host [`Tensor`].
pub fn literal_to_tensor(lit: &xla::Literal) -> Result<Tensor> {
    let shape = lit
        .array_shape()
        .map_err(|e| anyhow::anyhow!("literal shape: {e}"))?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let data: Vec<f32> = lit
        .to_vec()
        .map_err(|e| anyhow::anyhow!("literal data: {e}"))?;
    Ok(Tensor::from_vec(&dims, data))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts() -> Option<Runtime> {
        let dir = Path::new("artifacts");
        if dir.join("manifest.json").exists() {
            Some(Runtime::open(dir).unwrap())
        } else {
            None
        }
    }

    #[test]
    fn open_and_manifest() {
        let Some(rt) = artifacts() else { return };
        assert_eq!(rt.model.d_model, 64);
        assert_eq!(rt.model.n_experts, 8);
        assert_eq!(rt.bucket_for(3).unwrap(), 4);
        assert_eq!(rt.bucket_for(8).unwrap(), 8);
        assert!(rt.bucket_for(64).is_err());
    }

    #[test]
    fn expert_tile_executes_zero_weights() {
        let Some(rt) = artifacts() else { return };
        // zero weights => GELU(0)@W2 + 0 = 0 output
        let x = Tensor::full(&[64, 64], 0.5);
        let w1 = Tensor::zeros(&[64, 128]);
        let b1 = Tensor::zeros(&[128]);
        let w2 = Tensor::zeros(&[128, 64]);
        let b2 = Tensor::zeros(&[64]);
        let out = rt
            .execute("expert_tile", &[&x, &w1, &b1, &w2, &b2], &[])
            .unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].shape(), &[64, 64]);
        assert!(out[0].data().iter().all(|&v| v.abs() < 1e-6));
    }

    #[test]
    fn expert_tile_bias_path() {
        let Some(rt) = artifacts() else { return };
        // W1=0, W2=0, b2=c => out=c regardless of x
        let x = Tensor::zeros(&[64, 64]);
        let w1 = Tensor::zeros(&[64, 128]);
        let b1 = Tensor::full(&[128], 1.0);
        let w2 = Tensor::zeros(&[128, 64]);
        let b2 = Tensor::full(&[64], 2.5);
        let out = rt
            .execute("expert_tile", &[&x, &w1, &b1, &w2, &b2], &[])
            .unwrap();
        assert!(out[0].data().iter().all(|&v| (v - 2.5).abs() < 1e-5));
    }

    #[test]
    fn exec_count_increments() {
        let Some(rt) = artifacts() else { return };
        let before = *rt.exec_count.borrow();
        let x = Tensor::zeros(&[64, 64]);
        let w1 = Tensor::zeros(&[64, 128]);
        let b1 = Tensor::zeros(&[128]);
        let w2 = Tensor::zeros(&[128, 64]);
        let b2 = Tensor::zeros(&[64]);
        rt.execute("expert_tile", &[&x, &w1, &b1, &w2, &b2], &[])
            .unwrap();
        assert_eq!(*rt.exec_count.borrow(), before + 1);
    }

    #[test]
    fn missing_artifact_errors() {
        let Some(rt) = artifacts() else { return };
        assert!(rt.executable("no_such_module").is_err());
    }
}
