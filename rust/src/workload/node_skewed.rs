//! Seeded multi-node skewed router workload (DESIGN.md §13): hot
//! experts concentrated on one node, with a per-device decoy that makes
//! node-blind placement provably worse than node-aware placement.
//!
//! Construction, per expert `e` under the contiguous layout:
//!
//! * `home(e)` — the node one PAST the expert's contiguous node. Every
//!   token on a `home(e)` device boosts `e` by [`HOME_BOOST`], so the
//!   expert's traffic is *concentrated on one node* that is not the one
//!   the contiguous layout stores it on (the rebalancer has real
//!   headroom, and the hot low-id experts all home on the same node).
//! * `decoy(e)` — the FIRST device of the node after `home(e)`. Tokens
//!   on that single device boost `e` by [`DECOY_BOOST`] > [`HOME_BOOST`].
//!   A node-blind affinity policy compares per-device source loads, sees
//!   the decoy device beat every individual home-node device, and places
//!   `e` outside its home node; a node-aware policy aggregates per node
//!   first — `HOME_BOOST × node_size` beats the lone decoy — and keeps
//!   `e` with the bulk of its traffic. That gap is what the
//!   `dice exp topology` acceptance gate measures.
//!
//! On a flat/single-node topology the node structure is meaningless and
//! the preset degenerates to [`crate::placement::skewed_probs`].

use crate::moe::Placement;
use crate::netsim::Topology;
use crate::placement::skewed_probs;
use crate::rng::Rng;
use crate::tensor::Tensor;

/// Router-probability boost for tokens on the expert's home node.
pub const HOME_BOOST: f32 = 6.0;
/// Router-probability boost on the expert's single decoy device.
/// Strictly above [`HOME_BOOST`] per device, strictly below
/// `HOME_BOOST × node_size` in aggregate for every node of ≥ 2 devices.
pub const DECOY_BOOST: f32 = 9.0;

/// Synthetic node-skewed router probabilities `[n_tokens, n_experts]`
/// for a hierarchical `topo` over `devices`. Tokens shard contiguously
/// (token `i` belongs to device `i / (n_tokens/devices)`), matching
/// [`crate::moe::DispatchPlan::build`]. Rows are normalized
/// distributions; a per-token jitter keeps top-k sets varied; the same
/// seed always reproduces the same tensor.
pub fn node_skewed_probs(
    n_tokens: usize,
    n_experts: usize,
    devices: usize,
    topo: Topology,
    seed: u64,
) -> Tensor {
    assert!(devices > 0 && n_tokens % devices == 0, "tokens must shard evenly");
    if topo.is_flat(devices) {
        return skewed_probs(n_tokens, n_experts, devices, seed);
    }
    let nnodes = topo.nodes_for(devices);
    let contig = Placement::new(n_experts, devices);
    // per-expert home node and decoy device (see module docs)
    let home: Vec<usize> = (0..n_experts)
        .map(|e| (topo.node_of(contig.owner(e), devices) + 1) % nnodes)
        .collect();
    let decoy: Vec<usize> = (0..n_experts)
        .map(|e| topo.node_devices((home[e] + 1) % nnodes, devices).start)
        .collect();
    let tpd = n_tokens / devices;
    let mut rng = Rng::new(seed ^ 0x9E37_79B9_7F4A_7C15);
    let mut data = Vec::with_capacity(n_tokens * n_experts);
    for i in 0..n_tokens {
        let dev = i / tpd;
        let node = topo.node_of(dev, devices);
        let mut total = 0.0f32;
        let row_at = data.len();
        for e in 0..n_experts {
            let zipf = 1.0 / (1.0 + e as f32);
            let boost = if dev == decoy[e] {
                DECOY_BOOST
            } else if node == home[e] {
                HOME_BOOST
            } else {
                1.0
            };
            let jitter = 0.5 + rng.uniform_f32();
            let w = zipf * boost * jitter;
            data.push(w);
            total += w;
        }
        for w in &mut data[row_at..] {
            *w /= total;
        }
    }
    Tensor::from_vec(&[n_tokens, n_experts], data)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::moe::RoutingTable;
    use crate::placement::RoutingStats;

    #[test]
    fn rows_are_distributions_and_deterministic() {
        let topo = Topology::multinode(2);
        let p = node_skewed_probs(64, 8, 4, topo, 7);
        let (n, e) = p.rows();
        assert_eq!((n, e), (64, 8));
        for i in 0..n {
            let s: f32 = p.row(i).iter().sum();
            assert!((s - 1.0).abs() < 1e-5, "row {i} sums to {s}");
            assert!(p.row(i).iter().all(|&v| v > 0.0));
        }
        assert_eq!(node_skewed_probs(64, 8, 4, topo, 7), p);
        assert_ne!(node_skewed_probs(64, 8, 4, topo, 8), p);
    }

    #[test]
    fn flat_topology_degenerates_to_skewed_probs() {
        let flat = node_skewed_probs(32, 8, 4, Topology::flat(), 3);
        assert_eq!(flat, skewed_probs(32, 8, 4, 3));
        // one node == flat as well
        let one = node_skewed_probs(32, 8, 4, Topology::multinode(1), 3);
        assert_eq!(one, flat);
    }

    #[test]
    fn traffic_concentrates_on_the_home_node() {
        // each expert's aggregated source load must peak on its home
        // node — the structure the node-aware placement exploits.
        let topo = Topology::multinode(2);
        let (n_tokens, e_n, d_n) = (256usize, 8usize, 4usize);
        let probs = node_skewed_probs(n_tokens, e_n, d_n, topo, 0xD1CE);
        let rt = RoutingTable::from_probs(&probs, 2);
        let mut st = RoutingStats::new(e_n, d_n);
        st.observe(&rt, n_tokens / d_n);
        let contig = Placement::new(e_n, d_n);
        let nnodes = topo.nodes_for(d_n);
        for e in 0..e_n {
            let home = (topo.node_of(contig.owner(e), d_n) + 1) % nnodes;
            let at_home = st.node_src_load(e, topo, home);
            for n in 0..nnodes {
                if n != home {
                    assert!(
                        at_home > st.node_src_load(e, topo, n),
                        "expert {e}: home {home} load {at_home} vs node {n}"
                    );
                }
            }
        }
    }
}
