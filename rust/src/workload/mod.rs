//! Workload generation for the serving experiments: Poisson, uniform
//! and burst open-loop arrival processes, plus the named scenario
//! presets (steady / diurnal ramp / burst-recovery) in [`scenarios`]
//! and the seeded multi-node skewed routing preset in [`node_skewed`]
//! (hot experts concentrated on one node — the `dice exp topology`
//! harness and the cross-node scaling sweep share it).
//!
//! Traces are plain `Vec<Request>` sorted by arrival time, so they can
//! be generated once and replayed against any strategy or serving
//! policy (the comparison experiments depend on identical traces).

pub mod node_skewed;
pub mod scenarios;

pub use node_skewed::node_skewed_probs;
pub use scenarios::{burst_recovery_trace, diurnal_trace, Scenario};

use crate::rng::Rng;

/// A generation request.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Request {
    /// Trace-unique request id (position in the trace).
    pub id: usize,
    /// Class label to generate.
    pub label: usize,
    /// arrival time in (virtual) seconds from trace start.
    pub arrival: f64,
}

/// Poisson open-loop trace: exponential inter-arrivals at `rate` req/s.
pub fn poisson_trace(n: usize, rate: f64, n_classes: usize, seed: u64) -> Vec<Request> {
    let mut rng = Rng::new(seed);
    let mut t = 0.0;
    (0..n)
        .map(|id| {
            t += rng.exponential(rate);
            Request {
                id,
                label: rng.below(n_classes),
                arrival: t,
            }
        })
        .collect()
}

/// Uniform open-loop trace: fixed inter-arrival 1/rate.
pub fn uniform_trace(n: usize, rate: f64, n_classes: usize, seed: u64) -> Vec<Request> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|id| Request {
            id,
            label: rng.below(n_classes),
            arrival: (id + 1) as f64 / rate,
        })
        .collect()
}

/// A burst at t=0 (closed-loop saturation test).
pub fn burst_trace(n: usize, n_classes: usize, seed: u64) -> Vec<Request> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|id| Request {
            id,
            label: rng.below(n_classes),
            arrival: 0.0,
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_rate_and_monotone() {
        let tr = poisson_trace(5000, 10.0, 4, 1);
        assert_eq!(tr.len(), 5000);
        for w in tr.windows(2) {
            assert!(w[1].arrival >= w[0].arrival);
        }
        let span = tr.last().unwrap().arrival;
        let rate = 5000.0 / span;
        assert!((rate - 10.0).abs() < 0.6, "rate {rate}");
        assert!(tr.iter().all(|r| r.label < 4));
    }

    #[test]
    fn uniform_spacing() {
        let tr = uniform_trace(10, 2.0, 4, 0);
        assert!((tr[1].arrival - tr[0].arrival - 0.5).abs() < 1e-9);
    }

    #[test]
    fn burst_all_at_zero() {
        let tr = burst_trace(64, 4, 9);
        assert_eq!(tr.len(), 64);
        assert!(tr.iter().all(|r| r.arrival == 0.0));
        assert!(tr.iter().enumerate().all(|(i, r)| r.id == i));
        assert!(tr.iter().all(|r| r.label < 4));
    }

    #[test]
    fn traces_are_deterministic() {
        assert_eq!(poisson_trace(50, 5.0, 4, 7), poisson_trace(50, 5.0, 4, 7));
        assert_ne!(poisson_trace(50, 5.0, 4, 7), poisson_trace(50, 5.0, 4, 8));
    }
}
