//! Sampling jobs: drive the engine over many generation runs (the
//! rectified-flow Euler loop itself lives in `coordinator::engine`) and
//! merge the per-run statistics — the quality experiments generate
//! hundreds of samples in engine-sized chunks.

use anyhow::Result;

use crate::coordinator::{Engine, RunStats};
use crate::rng::Rng;
use crate::tensor::{ops, Tensor};

/// Aggregated outcome of a multi-run sampling job.
#[derive(Debug)]
pub struct JobResult {
    /// [N, C, S, S] samples across all runs.
    pub samples: Tensor,
    /// labels aligned with samples.
    pub labels: Vec<usize>,
    /// Cross-device activation bytes transferred across all runs.
    pub fresh_bytes: usize,
    /// Bytes avoided by conditional communication across all runs.
    pub saved_bytes: usize,
    /// Peak staleness-buffer bytes over all runs.
    pub peak_buffer_bytes: usize,
    /// Peak DistriFusion full-sequence buffer bytes over all runs.
    pub dfu_buffer_bytes: usize,
    /// Mean consumed-activation age (post-warmup), in diffusion steps.
    pub mean_staleness: f64,
    /// Max consumed-activation age (post-warmup), in diffusion steps.
    pub max_staleness: usize,
    /// Total PJRT executions issued.
    pub exec_calls: u64,
    /// Fraction of (token, expert) pairs transmitted fresh.
    pub fresh_fraction: f64,
    /// per-layer mean staleness (probe for Sec. 4.2).
    pub per_layer_staleness: Vec<f64>,
    /// per-expert assignment loads summed over all runs.
    pub expert_loads: Vec<usize>,
}

/// Generate `n_samples` with balanced class labels in chunks of
/// `global_batch`, seeds derived from `seed`.
pub fn sample_many(
    engine: &Engine,
    n_samples: usize,
    global_batch: usize,
    steps: usize,
    seed: u64,
) -> Result<JobResult> {
    assert!(n_samples % global_batch == 0, "n_samples must be a multiple of the batch");
    let n_classes = engine.rt.model.n_classes;
    let n_layers = engine.rt.model.n_layers;
    let mut rng = Rng::new(seed);
    let mut chunks = Vec::new();
    let mut labels = Vec::with_capacity(n_samples);
    let mut fresh_bytes = 0usize;
    let mut saved_bytes = 0usize;
    let mut peak_buf = 0usize;
    let mut dfu_buf = 0usize;
    let mut exec_calls = 0u64;
    let mut stale_sum = 0.0f64;
    let mut stale_n = 0usize;
    let mut max_stale = 0usize;
    let mut fresh_entries = 0usize;
    let mut total_entries = 0usize;
    let mut per_layer = vec![0.0f64; n_layers];
    let mut per_layer_n = 0usize;
    let mut expert_loads = vec![0usize; engine.rt.model.n_experts];

    let runs = n_samples / global_batch;
    for run in 0..runs {
        // balanced labels, shuffled per run
        let mut batch_labels: Vec<usize> =
            (0..global_batch).map(|i| i % n_classes).collect();
        rng.shuffle(&mut batch_labels);
        let run_seed = seed ^ ((run as u64 + 1) * 0x9E37_79B9);
        let (x, stats): (Tensor, RunStats) =
            engine.generate(&batch_labels, steps, run_seed, None)?;
        labels.extend_from_slice(&batch_labels);
        chunks.push(x);
        fresh_bytes += stats.fresh_bytes;
        saved_bytes += stats.saved_bytes;
        peak_buf = peak_buf.max(stats.peak_buffer_bytes);
        dfu_buf = dfu_buf.max(stats.dfu_buffer_bytes);
        exec_calls += stats.exec_calls;
        let warm = engine.cfg.opts.warmup_sync_steps;
        stale_sum += stats.staleness.mean_age(warm)
            * stats.staleness.records.len() as f64;
        stale_n += stats.staleness.records.len();
        max_stale = max_stale.max(stats.staleness.max_age(warm));
        fresh_entries += stats.comm.fresh_entries;
        total_entries += stats.comm.fresh_entries + stats.comm.reused_entries;
        for (acc, v) in per_layer.iter_mut().zip(stats.staleness.per_layer_mean(n_layers, warm)) {
            *acc += v;
        }
        per_layer_n += 1;
        for (acc, v) in expert_loads.iter_mut().zip(&stats.expert_loads) {
            *acc += v;
        }
    }
    for v in per_layer.iter_mut() {
        *v /= per_layer_n.max(1) as f64;
    }
    Ok(JobResult {
        samples: ops::concat_batch(&chunks),
        labels,
        fresh_bytes,
        saved_bytes,
        peak_buffer_bytes: peak_buf,
        dfu_buffer_bytes: dfu_buf,
        mean_staleness: if stale_n == 0 { 0.0 } else { stale_sum / stale_n as f64 },
        max_staleness: max_stale,
        exec_calls,
        fresh_fraction: if total_entries == 0 {
            1.0
        } else {
            fresh_entries as f64 / total_entries as f64
        },
        per_layer_staleness: per_layer,
        expert_loads,
    })
}
