//! Hierarchical interconnect topology (DESIGN.md §13): devices grouped
//! into nodes, with the intra-node fabric priced at the profile's
//! `a2a_bw`/`msg_latency` and the inter-node path priced at the NIC
//! (`nic_bw`/`nic_latency`), optionally oversubscribed.
//!
//! The flat topology is the degenerate single-node case: every pricing
//! path in [`crate::netsim::CostModel`] detects it (and the "uniform"
//! case where the NIC matches the intra fabric) and delegates to the
//! original flat formula, so flat prices stay **bit-identical** to the
//! pre-hierarchical model by construction.

use anyhow::{bail, ensure, Result};

/// The shape of the inter-node fabric.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TopologyKind {
    /// Single-switch view: every device on one fabric (today's model).
    Flat,
    /// Nodes joined by one NIC path each; inter-node traffic funnels
    /// through `nic_bw` serially (PCIe-box cluster over Ethernet/IB).
    MultiNode,
    /// Rail-optimized: one rail per local GPU index, so inter-node
    /// traffic is striped across `node_size` parallel NIC rails.
    Rail,
    /// Fat-tree with an oversubscription factor: the inter-node
    /// bandwidth every node sees is `nic_bw / oversub`.
    FatTree,
}

/// A hierarchical device topology: `nodes` groups of devices with
/// distinct intra-node and inter-node links.
///
/// Device→node assignment uses the same remainder-distributing block
/// scheme as [`crate::moe::Placement::new`]: the first `D mod N` nodes
/// hold one extra device, so any device count maps onto any node count.
/// `nodes == 0` means "auto": 8-GPU nodes (`devices.div_ceil(8)`).
///
/// # Examples
///
/// ```
/// use dice::netsim::Topology;
/// let t = Topology::parse("multinode:4").unwrap();
/// assert_eq!(t.name(), "multinode:4");
/// assert_eq!(t.nodes_for(16), 4);
/// assert_eq!(t.node_of(0, 16), 0);
/// assert_eq!(t.node_of(15, 16), 3);
/// // flat is the degenerate one-node case
/// assert!(Topology::flat().is_flat(16));
/// assert!(!t.is_flat(16));
/// ```
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Topology {
    /// Inter-node fabric shape.
    pub kind: TopologyKind,
    /// Node count (0 = auto: one node per 8 devices).
    pub nodes: usize,
    /// Fat-tree oversubscription factor (≥ 1.0; 1.0 elsewhere).
    pub oversub: f64,
}

impl Topology {
    /// The flat (single-node) topology — today's model.
    pub fn flat() -> Topology {
        Topology {
            kind: TopologyKind::Flat,
            nodes: 1,
            oversub: 1.0,
        }
    }

    /// Multi-node topology with `nodes` nodes (0 = auto).
    pub fn multinode(nodes: usize) -> Topology {
        Topology {
            kind: TopologyKind::MultiNode,
            nodes,
            oversub: 1.0,
        }
    }

    /// Rail-optimized topology with `nodes` nodes (0 = auto).
    pub fn rail(nodes: usize) -> Topology {
        Topology {
            kind: TopologyKind::Rail,
            nodes,
            oversub: 1.0,
        }
    }

    /// Fat-tree topology with oversubscription `oversub` (≥ 1.0) and
    /// `nodes` nodes (0 = auto).
    pub fn fattree(oversub: f64, nodes: usize) -> Topology {
        assert!(oversub.is_finite() && oversub >= 1.0, "oversub {oversub} < 1");
        Topology {
            kind: TopologyKind::FatTree,
            nodes,
            oversub,
        }
    }

    /// Parse a CLI spec: `flat | multinode[:<nodes>] | rail[:<nodes>] |
    /// fattree:<oversub>[:<nodes>]`. Omitted node counts mean auto
    /// (8-GPU nodes).
    pub fn parse(s: &str) -> Result<Topology> {
        let parts: Vec<&str> = s.split(':').collect();
        let nodes_arg = |p: Option<&&str>| -> Result<usize> {
            match p {
                None => Ok(0),
                Some(v) => match v.parse::<usize>() {
                    Ok(n) if n >= 1 => Ok(n),
                    _ => bail!("bad node count {v:?} in topology {s:?}"),
                },
            }
        };
        match parts[0] {
            "flat" => {
                ensure!(parts.len() == 1, "flat takes no arguments: {s:?}");
                Ok(Topology::flat())
            }
            "multinode" => {
                ensure!(parts.len() <= 2, "multinode takes one argument: {s:?}");
                Ok(Topology::multinode(nodes_arg(parts.get(1))?))
            }
            "rail" => {
                ensure!(parts.len() <= 2, "rail takes one argument: {s:?}");
                Ok(Topology::rail(nodes_arg(parts.get(1))?))
            }
            "fattree" => {
                ensure!(
                    parts.len() == 2 || parts.len() == 3,
                    "fattree needs an oversubscription factor: {s:?}"
                );
                let o: f64 = match parts[1].parse() {
                    Ok(o) if f64::is_finite(o) && o >= 1.0 => o,
                    _ => bail!("bad oversubscription {:?} in topology {s:?} (need >= 1)", parts[1]),
                };
                Ok(Topology::fattree(o, nodes_arg(parts.get(2))?))
            }
            _ => bail!("unknown topology {s:?} (flat|multinode:<n>|rail[:<n>]|fattree:<o>[:<n>])"),
        }
    }

    /// Canonical spec string; `parse(name())` round-trips.
    pub fn name(&self) -> String {
        match self.kind {
            TopologyKind::Flat => "flat".into(),
            TopologyKind::MultiNode if self.nodes == 0 => "multinode".into(),
            TopologyKind::MultiNode => format!("multinode:{}", self.nodes),
            TopologyKind::Rail if self.nodes == 0 => "rail".into(),
            TopologyKind::Rail => format!("rail:{}", self.nodes),
            TopologyKind::FatTree if self.nodes == 0 => format!("fattree:{}", self.oversub),
            TopologyKind::FatTree => format!("fattree:{}:{}", self.oversub, self.nodes),
        }
    }

    /// Effective node count for `devices`: flat is always 1 node; auto
    /// (`nodes == 0`) packs 8 devices per node; explicit counts clamp so
    /// every node holds at least one device.
    pub fn nodes_for(&self, devices: usize) -> usize {
        if self.kind == TopologyKind::Flat {
            return 1;
        }
        let n = if self.nodes == 0 { devices.div_ceil(8) } else { self.nodes };
        n.clamp(1, devices.max(1))
    }

    /// Node of `device` under the remainder-distributing block scheme
    /// (first `D mod N` nodes hold one extra device).
    pub fn node_of(&self, device: usize, devices: usize) -> usize {
        let n = self.nodes_for(devices);
        let base = devices / n;
        let rem = devices % n;
        let big = (base + 1) * rem;
        if device < big {
            device / (base + 1)
        } else {
            rem + (device - big) / base
        }
    }

    /// The device-index range node `node` holds.
    pub fn node_devices(&self, node: usize, devices: usize) -> std::ops::Range<usize> {
        let n = self.nodes_for(devices);
        assert!(node < n, "node {node} out of range ({n} nodes)");
        let base = devices / n;
        let rem = devices % n;
        if node < rem {
            let start = node * (base + 1);
            start..start + base + 1
        } else {
            let start = (base + 1) * rem + (node - rem) * base;
            start..start + base
        }
    }

    /// Size of the largest node (the first node under the block scheme).
    pub fn max_node_size(&self, devices: usize) -> usize {
        let n = self.nodes_for(devices);
        devices / n + usize::from(devices % n > 0)
    }

    /// True when the topology degenerates to a single node over
    /// `devices` — flat by kind, or any topology that resolves to ≤ 1
    /// effective node. Flat-degenerate topologies are priced by the
    /// original flat formula, bit-exactly.
    pub fn is_flat(&self, devices: usize) -> bool {
        devices <= 1 || self.nodes_for(devices) <= 1
    }

    /// Fraction of all-to-all traffic that crosses node boundaries under
    /// balanced routing: a uniformly-random (src, dst) pair among the
    /// `D·(D−1)` crossing pairs lands on different nodes with
    /// probability `(D² − Σ_n size_n²) / (D·(D−1))`.
    pub fn inter_frac(&self, devices: usize) -> f64 {
        if self.is_flat(devices) {
            return 0.0;
        }
        let n = self.nodes_for(devices);
        let base = devices / n;
        let rem = devices % n;
        let sq = rem * (base + 1) * (base + 1) + (n - rem) * base * base;
        let d = devices as f64;
        (d * d - sq as f64) / (d * (d - 1.0))
    }

    /// FNV-1a key over (kind, nodes, oversub bits) — lets pricing memos
    /// (e.g. [`crate::moe::DispatchPlan::cross_bytes_split`]) tell
    /// topologies apart without storing the struct.
    pub fn key(&self) -> u64 {
        const PRIME: u64 = 0x0000_0100_0000_01b3;
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for v in [self.kind as u64, self.nodes as u64, self.oversub.to_bits()] {
            h = (h ^ v.wrapping_add(1)).wrapping_mul(PRIME);
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_name_roundtrip() {
        for spec in [
            "flat",
            "multinode",
            "multinode:4",
            "rail",
            "rail:2",
            "fattree:2",
            "fattree:1.5:4",
        ] {
            let t = Topology::parse(spec).unwrap();
            assert_eq!(t.name(), spec, "{spec}");
            assert_eq!(Topology::parse(&t.name()).unwrap(), t);
        }
    }

    #[test]
    fn parse_rejects_malformed_specs() {
        for bad in [
            "",
            "mesh",
            "flat:2",
            "multinode:0",
            "multinode:x",
            "multinode:2:3",
            "rail:0",
            "fattree",
            "fattree:0.5",
            "fattree:nan",
            "fattree:-2",
            "fattree:2:0",
            "fattree:2:4:8",
        ] {
            assert!(Topology::parse(bad).is_err(), "{bad:?} must be rejected");
        }
    }

    #[test]
    fn node_blocks_distribute_remainder() {
        let t = Topology::multinode(3);
        // 8 devices over 3 nodes: 3-3-2 (same scheme as Placement::new)
        assert_eq!(t.nodes_for(8), 3);
        let nodes: Vec<usize> = (0..8).map(|d| t.node_of(d, 8)).collect();
        assert_eq!(nodes, vec![0, 0, 0, 1, 1, 1, 2, 2]);
        assert_eq!(t.node_devices(0, 8), 0..3);
        assert_eq!(t.node_devices(2, 8), 6..8);
        assert_eq!(t.max_node_size(8), 3);
        // node_of and node_devices must agree everywhere
        for n in 0..3 {
            for d in t.node_devices(n, 8) {
                assert_eq!(t.node_of(d, 8), n);
            }
        }
    }

    #[test]
    fn auto_nodes_pack_eight_devices() {
        let t = Topology::multinode(0);
        assert_eq!(t.nodes_for(8), 1);
        assert_eq!(t.nodes_for(16), 2);
        assert_eq!(t.nodes_for(65), 9);
        // explicit counts clamp to one device per node minimum
        assert_eq!(Topology::multinode(16).nodes_for(4), 4);
    }

    #[test]
    fn flat_degenerate_cases() {
        assert!(Topology::flat().is_flat(64));
        assert!(Topology::multinode(1).is_flat(64));
        assert!(Topology::multinode(4).is_flat(1));
        assert!(Topology::multinode(0).is_flat(8), "auto: 8 devices fit one node");
        assert!(!Topology::multinode(4).is_flat(8));
        assert_eq!(Topology::flat().inter_frac(64), 0.0);
    }

    #[test]
    fn inter_frac_balanced_routing() {
        // 2 equal nodes of 2: 4 crossing-pair slots of 12 stay intra...
        // D²−Σs² = 16−8 = 8 inter pairs of D(D−1) = 12 crossing pairs.
        let t = Topology::multinode(2);
        assert!((t.inter_frac(4) - 8.0 / 12.0).abs() < 1e-12);
        // more nodes at fixed devices ⇒ larger inter share
        let f2 = Topology::multinode(2).inter_frac(16);
        let f4 = Topology::multinode(4).inter_frac(16);
        let f8 = Topology::multinode(8).inter_frac(16);
        assert!(f2 < f4 && f4 < f8, "{f2} {f4} {f8}");
        assert!(f8 < 1.0);
    }

    #[test]
    fn keys_distinguish_topologies() {
        let ts = [
            Topology::flat(),
            Topology::multinode(2),
            Topology::multinode(4),
            Topology::rail(4),
            Topology::fattree(2.0, 4),
            Topology::fattree(4.0, 4),
        ];
        for (i, a) in ts.iter().enumerate() {
            for (j, b) in ts.iter().enumerate() {
                assert_eq!(a.key() == b.key(), i == j, "{a:?} vs {b:?}");
            }
        }
    }
}
