//! Interconnect + device cost model (the paper's 8×4090 / 8×3080 PCIe
//! testbeds, DESIGN.md §2 substitution table).
//!
//! Everything here is an *analytic* model: per-op FLOP counts for the
//! DiT-MoE block, α+β transfer costs for the collectives, and a byte-
//! accurate memory model (parameters, activations, staleness buffers) —
//! enough to reproduce the paper's Table 5 (a2a share), Figure 9/14/15
//! (latency & memory scaling) and the OOM behaviour of DistriFusion.
//! Absolute seconds are calibrated, ratios are the claim.

use crate::compress;
use crate::config::{CompressionCodec, HardwareProfile, ModelConfig};

/// Serving precision assumed by the cost model (bytes per element).
pub const ELEM_BYTES: f64 = 2.0;

/// Workload point: a model served on `devices` GPUs at `local_batch`
/// images per device with `tokens` tokens per image.
#[derive(Debug, Clone, Copy)]
pub struct Workload {
    /// Images per device.
    pub local_batch: usize,
    /// GPU count.
    pub devices: usize,
    /// Tokens per image.
    pub tokens: usize,
}

impl Workload {
    /// Total images in flight (local_batch × devices).
    pub fn global_batch(&self) -> usize {
        self.local_batch * self.devices
    }
    /// Tokens processed per device per step (non-expert layers).
    pub fn local_tokens(&self) -> usize {
        self.local_batch * self.tokens
    }
}

/// Per-layer cost components (seconds / bytes), derived from the model
/// dims and a hardware profile.
#[derive(Debug, Clone, Copy)]
pub struct LayerCosts {
    /// attention + adaLN + router compute.
    pub t_pre: f64,
    /// routed expert FFN compute for the device's share of dispatched
    /// tokens (balanced routing assumed; the engine measures the real
    /// imbalance in numerics mode).
    pub t_expert: f64,
    /// shared expert + residual compute.
    pub t_post: f64,
    /// one all-to-all (dispatch or combine) latency for full freshness.
    pub t_a2a: f64,
    /// bytes a single device sends in one all-to-all.
    pub a2a_bytes: f64,
}

/// Analytic cost model.
///
/// # Examples
///
/// ```
/// use dice::config::{hardware_profile, model_preset, CompressionCodec};
/// use dice::netsim::{CostModel, Workload};
///
/// let cm = CostModel::new(
///     model_preset("xl").unwrap(),
///     hardware_profile("rtx4090_pcie").unwrap(),
/// );
/// let wl = Workload { local_batch: 8, devices: 8, tokens: cm.model.tokens() };
/// let c = cm.layer_costs(&wl);
/// // the paper's bottleneck: the two all-to-alls outweigh the block compute
/// assert!(2.0 * c.t_a2a > c.t_pre + c.t_expert + c.t_post);
/// // int8 residual compression moves fewer bytes than the dense payload
/// let dense = cm.a2a_wire_bytes(&wl, CompressionCodec::None, 1.0);
/// let int8 = cm.a2a_wire_bytes(&wl, CompressionCodec::Int8, 1.0);
/// assert!(int8 < dense);
/// ```
#[derive(Debug, Clone)]
pub struct CostModel {
    /// Model architecture being priced.
    pub model: ModelConfig,
    /// Hardware profile the costs are calibrated to.
    pub hw: HardwareProfile,
}

impl CostModel {
    /// Bind a model architecture to a hardware profile.
    pub fn new(model: ModelConfig, hw: HardwareProfile) -> CostModel {
        CostModel { model, hw }
    }

    /// FLOPs of the attention half of a block for `n` tokens
    /// (qkv + proj GEMMs + 2·T·T·D attention matmuls + adaLN).
    pub fn flops_pre(&self, wl: &Workload) -> f64 {
        let d = self.model.d_model as f64;
        let n = wl.local_tokens() as f64;
        let t = self.model.tokens() as f64;
        let b = wl.local_batch as f64;
        let qkv = 2.0 * n * d * 3.0 * d;
        let proj = 2.0 * n * d * d;
        let attn = 2.0 * 2.0 * b * t * t * d;
        let adaln = 2.0 * b * d * 6.0 * d;
        let router = 2.0 * n * d * self.model.n_experts as f64;
        qkv + proj + attn + adaln + router
    }

    /// FLOPs of the routed experts executed on ONE device per layer:
    /// each device receives `local_tokens * top_k` token-assignments on
    /// average (balanced routing).
    pub fn flops_expert(&self, wl: &Workload) -> f64 {
        let d = self.model.d_model as f64;
        let f = self.model.d_ffn as f64;
        let assignments = wl.local_tokens() as f64 * self.model.top_k as f64;
        2.0 * assignments * (d * f + f * d)
    }

    /// FLOPs of shared experts + residual on the local shard.
    pub fn flops_post(&self, wl: &Workload) -> f64 {
        let d = self.model.d_model as f64;
        let f = self.model.d_ffn as f64;
        let n = wl.local_tokens() as f64;
        2.0 * n * self.model.n_shared as f64 * (d * f + f * d) + 4.0 * n * d
    }

    /// Bytes one device contributes to a single all-to-all (dispatch or
    /// combine): the crossing rows ([`CostModel::a2a_rows`]) at width D
    /// and serving precision.
    pub fn a2a_bytes(&self, wl: &Workload) -> f64 {
        self.a2a_rows(wl) * self.model.d_model as f64 * ELEM_BYTES
    }

    /// Token-rows one device contributes to a single all-to-all that
    /// actually cross the wire (`local_tokens · top_k` routed rows, of
    /// which `(devices-1)/devices` leave the device).
    pub fn a2a_rows(&self, wl: &Workload) -> f64 {
        let cross = (wl.devices - 1) as f64 / wl.devices as f64;
        wl.local_tokens() as f64 * self.model.top_k as f64 * cross
    }

    /// Bytes one device contributes to a single all-to-all after the
    /// residual codec, with `fresh_frac` of the rows actually travelling
    /// (conditional communication throttles the rest). `None` prices the
    /// dense payload — identical to [`CostModel::a2a_bytes`] at
    /// `fresh_frac = 1.0`. The per-device payload is treated as one
    /// encoded block (one per-channel scale vector per collective).
    pub fn a2a_wire_bytes(&self, wl: &Workload, codec: CompressionCodec, fresh_frac: f64) -> f64 {
        let rows = self.a2a_rows(wl) * fresh_frac;
        let d = self.model.d_model;
        match compress::build(codec) {
            None => rows * d as f64 * ELEM_BYTES,
            Some(c) => c.wire_bytes(rows, d, ELEM_BYTES),
        }
    }

    /// α+β-style codec overhead for one all-to-all: fixed encode+decode
    /// launch cost plus the raw payload streamed through the profile's
    /// fused quantize/sparsify throughput (`codec_bw`). Zero when
    /// compression is off; the *identity* codec pays the overhead
    /// without saving bytes, which is exactly why it is the baseline.
    pub fn t_codec(&self, wl: &Workload, codec: CompressionCodec, fresh_frac: f64) -> f64 {
        if codec == CompressionCodec::None {
            return 0.0;
        }
        let raw = self.a2a_rows(wl) * fresh_frac * self.model.d_model as f64 * ELEM_BYTES;
        0.5 * self.hw.coll_overhead + raw / self.hw.codec_bw
    }

    /// All-to-all latency for `bytes` per device: all traffic funnels
    /// through the PCIe host bridge, so effective per-device bandwidth is
    /// `a2a_bw / devices` (this is what makes 8-GPU shares exceed 4-GPU
    /// shares in Table 5).
    pub fn t_a2a(&self, bytes: f64, devices: usize) -> f64 {
        self.hw.coll_overhead
            + self.hw.msg_latency * (devices - 1) as f64
            + bytes * devices as f64 / self.hw.a2a_bw
    }

    /// Point-to-point transfer latency.
    pub fn t_p2p(&self, bytes: f64) -> f64 {
        self.hw.msg_latency + bytes / self.hw.link_bw
    }

    /// Placement-rebalance migration latency (DESIGN.md §9): the moved
    /// experts' weights travel point-to-point between the old and new
    /// owner at f16 serving precision, as one bulk transfer. Zero moves
    /// cost zero (no α term — nothing is launched).
    pub fn t_migrate(&self, moved_experts: usize) -> f64 {
        if moved_experts == 0 {
            return 0.0;
        }
        self.t_p2p(moved_experts as f64 * self.model.expert_param_bytes() as f64)
    }

    /// All-to-all latency priced from a MEASURED engine dispatch plan
    /// rather than the analytic balanced-routing payload: the crossing
    /// bytes come from [`crate::moe::DispatchPlan::cross_bytes`], whose
    /// per-plan memo means pricing both collectives of every layer from
    /// one plan scans the entries once, not once per priced collective.
    ///
    /// This is the moe↔netsim pricing contract: `moe` decides *which*
    /// rows cross (source device vs. the placement's owner map — so a
    /// rebalanced [`crate::moe::Placement`] changes the payload, which
    /// is why the memo keys on the map fingerprint), and this model
    /// decides *what the bytes cost* (α+β under host-bridge contention).
    /// The analytic [`CostModel::a2a_bytes`] path assumes balanced
    /// routing with a `(D-1)/D` crossing fraction; placement policies
    /// feed their measured fraction into the virtual-time schedules via
    /// `DiceOptions::a2a_cross_scale` instead (DESIGN.md §9).
    pub fn t_a2a_measured(
        &self,
        plan: &crate::moe::DispatchPlan,
        placement: &crate::moe::Placement,
    ) -> f64 {
        let bytes = plan.cross_bytes(placement, self.model.d_model, ELEM_BYTES as usize) as f64;
        self.t_a2a(bytes, placement.devices)
    }

    /// Effective compute time: small batches under-utilise the GPU, so
    /// throughput ramps with the resident token count and saturates at
    /// the profile's peak (this is why the paper's a2a share RISES with
    /// batch — comm scales linearly while compute scales sublinearly).
    pub fn t_compute_at(&self, flops: f64, local_tokens: usize) -> f64 {
        let n = local_tokens as f64;
        let util = n / (n + self.hw.sat_tokens);
        flops / (self.hw.flops * util)
    }

    /// Compute time at full utilisation (saturated batch).
    pub fn t_compute(&self, flops: f64) -> f64 {
        flops / self.hw.flops
    }

    /// All per-layer costs for a workload.
    pub fn layer_costs(&self, wl: &Workload) -> LayerCosts {
        let bytes = self.a2a_bytes(wl);
        let n = wl.local_tokens();
        LayerCosts {
            t_pre: self.t_compute_at(self.flops_pre(wl), n),
            t_expert: self.t_compute_at(self.flops_expert(wl), n),
            t_post: self.t_compute_at(self.flops_post(wl), n),
            t_a2a: self.t_a2a(bytes, wl.devices),
            a2a_bytes: bytes,
        }
    }

    /// Embed + cond + final compute (once per step, replicated).
    pub fn t_affix(&self, wl: &Workload) -> f64 {
        let d = self.model.d_model as f64;
        let n = wl.local_tokens() as f64;
        let pd = self.model.patch_dim() as f64;
        self.t_compute_at(
            2.0 * n * pd * d + 2.0 * n * d * pd + 4.0 * wl.local_batch as f64 * d * d,
            wl.local_tokens(),
        )
    }

    // ----------------------------------------------------------------
    // Memory model (bytes per device)
    // ----------------------------------------------------------------

    /// Peak activation working set per device (a few [B,T,D]-sized live
    /// tensors during a block).
    pub fn activation_bytes(&self, wl: &Workload) -> f64 {
        let live_tensors = 6.0;
        wl.local_tokens() as f64 * self.model.d_model as f64 * ELEM_BYTES * live_tensors
    }

    /// Staleness-buffer bytes per device for a strategy that persists
    /// `buffers_per_layer` activation-sized buffers across steps
    /// (displaced EP: 2 = dispatch + combine; interweaved: 1 = combine
    /// only — the paper's "half the buffer size").
    pub fn staleness_buffer_bytes(&self, wl: &Workload, buffers_per_layer: f64) -> f64 {
        let per_layer =
            wl.local_tokens() as f64 * self.model.top_k as f64 * self.model.d_model as f64 * ELEM_BYTES;
        buffers_per_layer * self.model.n_layers as f64 * per_layer
    }

    /// DistriFusion staleness buffers: every device keeps full-sequence
    /// copies of each asynchronously-exchanged tensor per layer —
    /// DistriFusion buffers the boundary activations of every comm op
    /// (block input, K, V and their in-flight send/recv doubles),
    /// ~12 full-sequence tensors per layer at fp16. This is what drives
    /// the paper's DistriFusion OOM at XL batch >= 16.
    pub fn dfu_buffer_bytes(&self, wl: &Workload) -> f64 {
        const BUFS_PER_LAYER: f64 = 12.0; // (input + K + V) x (live + send + recv)
        BUFS_PER_LAYER
            * self.model.n_layers as f64
            * wl.global_batch() as f64
            * self.model.tokens() as f64
            * self.model.d_model as f64
            * ELEM_BYTES
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{hardware_profile, model_preset};

    fn xl8(batch: usize) -> (CostModel, Workload) {
        let cm = CostModel::new(
            model_preset("xl").unwrap(),
            hardware_profile("rtx4090_pcie").unwrap(),
        );
        let tokens = cm.model.tokens();
        (
            cm,
            Workload {
                local_batch: batch,
                devices: 8,
                tokens,
            },
        )
    }

    #[test]
    fn a2a_dominates_at_xl_scale() {
        // Paper Table 5: a2a share 75-79% on 8 GPUs for XL. At the level
        // of a single layer that means 2·t_a2a >> compute.
        let (cm, wl) = xl8(8);
        let c = cm.layer_costs(&wl);
        let comm = 2.0 * c.t_a2a;
        let comp = c.t_pre + c.t_expert + c.t_post;
        let share = comm / (comm + comp);
        assert!(share > 0.6 && share < 0.9, "a2a share {share}");
    }

    #[test]
    fn a2a_share_grows_with_batch() {
        let shares: Vec<f64> = [4, 8, 16, 32]
            .iter()
            .map(|&b| {
                let (cm, wl) = xl8(b);
                let c = cm.layer_costs(&wl);
                2.0 * c.t_a2a / (2.0 * c.t_a2a + c.t_pre + c.t_expert + c.t_post)
            })
            .collect();
        for w in shares.windows(2) {
            assert!(w[1] >= w[0] - 1e-9, "{shares:?}");
        }
    }

    #[test]
    fn bytes_scale_linearly_with_batch() {
        let (cm, wl4) = xl8(4);
        let (_, wl8) = xl8(8);
        let r = cm.a2a_bytes(&wl8) / cm.a2a_bytes(&wl4);
        assert!((r - 2.0).abs() < 1e-9);
    }

    #[test]
    fn interweaved_buffer_is_half_displaced() {
        let (cm, wl) = xl8(8);
        let disp = cm.staleness_buffer_bytes(&wl, 2.0);
        let intw = cm.staleness_buffer_bytes(&wl, 1.0);
        assert!((disp / intw - 2.0).abs() < 1e-9);
    }

    #[test]
    fn dfu_ooms_on_g_but_ep_fits() {
        let g = model_preset("g").unwrap();
        let hw = hardware_profile("rtx4090_pcie").unwrap();
        // DistriFusion replicates the full model: > 24 GB => OOM.
        assert!(g.param_bytes() > hw.mem_bytes);
        // EP on 8 devices shards the experts: fits.
        assert!(g.param_bytes_per_device_ep(8) < hw.mem_bytes);
    }

    #[test]
    fn codec_wire_bytes_ordering_and_consistency() {
        let (cm, wl) = xl8(8);
        let dense = cm.a2a_wire_bytes(&wl, CompressionCodec::None, 1.0);
        assert!((dense - cm.a2a_bytes(&wl)).abs() < 1e-6, "None == dense payload");
        let id = cm.a2a_wire_bytes(&wl, CompressionCodec::Identity, 1.0);
        assert!((id - dense).abs() < 1e-6, "identity saves nothing");
        let int8 = cm.a2a_wire_bytes(&wl, CompressionCodec::Int8, 1.0);
        let topk = cm.a2a_wire_bytes(&wl, CompressionCodec::TopK, 1.0);
        assert!(int8 < dense, "int8 {int8} vs dense {dense}");
        assert!(topk < int8, "topk {topk} vs int8 {int8}");
        // at f16 serving precision int8 halves the payload (+ scales)
        assert!(int8 / dense > 0.45 && int8 / dense < 0.55, "{}", int8 / dense);
        // throttled rows compress proportionally
        let int8_cc = cm.a2a_wire_bytes(&wl, CompressionCodec::Int8, 0.75);
        assert!(int8_cc < int8);
    }

    #[test]
    fn codec_overhead_is_alpha_beta() {
        let (cm, wl) = xl8(8);
        assert_eq!(cm.t_codec(&wl, CompressionCodec::None, 1.0), 0.0);
        let t1 = cm.t_codec(&wl, CompressionCodec::Int8, 1.0);
        let t2 = cm.t_codec(&wl, CompressionCodec::Int8, 0.5);
        // α survives at small payloads, β scales with the raw bytes
        assert!(t1 > t2 && t2 > 0.5 * cm.hw.coll_overhead);
        // the overhead must stay well under the a2a it shortens,
        // otherwise compression could never win
        let c = cm.layer_costs(&wl);
        assert!(t1 < 0.1 * c.t_a2a, "codec {t1} vs a2a {}", c.t_a2a);
    }

    #[test]
    fn measured_plan_pricing_matches_direct_formula() {
        use crate::moe::{DispatchPlan, Placement, RoutingTable};
        use crate::tensor::Tensor;
        let cm = CostModel::new(
            model_preset("xl").unwrap(),
            hardware_profile("rtx4090_pcie").unwrap(),
        );
        // 8 tokens on 2 devices, every token to both of 2 experts
        let probs = Tensor::from_vec(&[8, 2], vec![0.6, 0.4].repeat(8));
        let rt = RoutingTable::from_probs(&probs, 2);
        let plan = DispatchPlan::build(&rt, 4);
        let p = Placement::new(2, 2);
        let direct = cm.t_a2a(
            plan.cross_bytes(&p, cm.model.d_model, ELEM_BYTES as usize) as f64,
            2,
        );
        let measured = cm.t_a2a_measured(&plan, &p);
        assert_eq!(measured, direct);
        // second call serves the byte count from the plan's memo
        assert_eq!(cm.t_a2a_measured(&plan, &p), measured);
        assert!(measured > 0.0);
    }

    #[test]
    fn migration_pricing_scales_with_moved_experts() {
        let (cm, wl) = xl8(8);
        assert_eq!(cm.t_migrate(0), 0.0, "no moves, no launch");
        let one = cm.t_migrate(1);
        let four = cm.t_migrate(4);
        assert!(one > 0.0);
        // one bulk transfer: α paid once, β scales with the payload
        assert!(four > 3.0 * one / 2.0 && four < 4.0 * one);
        // a handful of moved experts must cost less than one full
        // 50-step run's all-to-all time, or rebalancing could never pay
        let c = cm.layer_costs(&wl);
        assert!(four < 2.0 * c.t_a2a * cm.model.n_layers as f64 * 50.0);
    }

    #[test]
    fn nvlink_kills_the_bottleneck() {
        let cm = CostModel::new(
            model_preset("xl").unwrap(),
            hardware_profile("nvlink").unwrap(),
        );
        let wl = Workload {
            local_batch: 8,
            devices: 8,
            tokens: cm.model.tokens(),
        };
        let c = cm.layer_costs(&wl);
        let share = 2.0 * c.t_a2a / (2.0 * c.t_a2a + c.t_pre + c.t_expert + c.t_post);
        assert!(share < 0.45, "nvlink a2a share {share}");
    }
}
