//! Interconnect + device cost model (the paper's 8×4090 / 8×3080 PCIe
//! testbeds, DESIGN.md §2 substitution table).
//!
//! Everything here is an *analytic* model: per-op FLOP counts for the
//! DiT-MoE block, α+β transfer costs for the collectives, and a byte-
//! accurate memory model (parameters, activations, staleness buffers) —
//! enough to reproduce the paper's Table 5 (a2a share), Figure 9/14/15
//! (latency & memory scaling) and the OOM behaviour of DistriFusion.
//! Absolute seconds are calibrated, ratios are the claim.

pub mod topology;

use crate::compress;
use crate::config::{CompressionCodec, HardwareProfile, ModelConfig};

pub use topology::{Topology, TopologyKind};

/// Serving precision assumed by the cost model (bytes per element).
pub const ELEM_BYTES: f64 = 2.0;

/// Workload point: a model served on `devices` GPUs at `local_batch`
/// images per device with `tokens` tokens per image.
#[derive(Debug, Clone, Copy)]
pub struct Workload {
    /// Images per device.
    pub local_batch: usize,
    /// GPU count.
    pub devices: usize,
    /// Tokens per image.
    pub tokens: usize,
}

impl Workload {
    /// Total images in flight (local_batch × devices).
    pub fn global_batch(&self) -> usize {
        self.local_batch * self.devices
    }
    /// Tokens processed per device per step (non-expert layers).
    pub fn local_tokens(&self) -> usize {
        self.local_batch * self.tokens
    }
}

/// Per-layer cost components (seconds / bytes), derived from the model
/// dims and a hardware profile.
#[derive(Debug, Clone, Copy)]
pub struct LayerCosts {
    /// attention + adaLN + router compute.
    pub t_pre: f64,
    /// routed expert FFN compute for the device's share of dispatched
    /// tokens (balanced routing assumed; the engine measures the real
    /// imbalance in numerics mode).
    pub t_expert: f64,
    /// shared expert + residual compute.
    pub t_post: f64,
    /// one all-to-all (dispatch or combine) latency for full freshness.
    pub t_a2a: f64,
    /// bytes a single device sends in one all-to-all.
    pub a2a_bytes: f64,
}

/// Analytic cost model.
///
/// # Examples
///
/// ```
/// use dice::config::{hardware_profile, model_preset, CompressionCodec};
/// use dice::netsim::{CostModel, Workload};
///
/// let cm = CostModel::new(
///     model_preset("xl").unwrap(),
///     hardware_profile("rtx4090_pcie").unwrap(),
/// );
/// let wl = Workload { local_batch: 8, devices: 8, tokens: cm.model.tokens() };
/// let c = cm.layer_costs(&wl);
/// // the paper's bottleneck: the two all-to-alls outweigh the block compute
/// assert!(2.0 * c.t_a2a > c.t_pre + c.t_expert + c.t_post);
/// // int8 residual compression moves fewer bytes than the dense payload
/// let dense = cm.a2a_wire_bytes(&wl, CompressionCodec::None, 1.0);
/// let int8 = cm.a2a_wire_bytes(&wl, CompressionCodec::Int8, 1.0);
/// assert!(int8 < dense);
/// ```
#[derive(Debug, Clone)]
pub struct CostModel {
    /// Model architecture being priced.
    pub model: ModelConfig,
    /// Hardware profile the costs are calibrated to.
    pub hw: HardwareProfile,
    /// Interconnect topology the collectives are priced over (flat by
    /// default — the degenerate single-node case, bit-identical to the
    /// pre-hierarchical model).
    pub topo: Topology,
}

impl CostModel {
    /// Bind a model architecture to a hardware profile (flat topology).
    pub fn new(model: ModelConfig, hw: HardwareProfile) -> CostModel {
        CostModel {
            model,
            hw,
            topo: Topology::flat(),
        }
    }

    /// Price over a hierarchical topology instead of the flat default
    /// (DESIGN.md §13).
    pub fn with_topology(mut self, topo: Topology) -> CostModel {
        self.topo = topo;
        self
    }

    /// True when `devices` under this model's topology actually splits
    /// into nodes with a *distinct* inter-node path: more than one
    /// effective node AND either a rail fabric, oversubscription, or a
    /// NIC that differs from the intra-node fabric. Uniform hierarchies
    /// (NIC == intra bandwidth/latency, no oversubscription, no rails)
    /// are priced by the flat formula so they collapse to it bit-exactly
    /// instead of merely approximately (float re-association).
    fn hierarchical(&self, devices: usize) -> bool {
        !self.topo.is_flat(devices)
            && (self.topo.kind == TopologyKind::Rail
                || self.topo.oversub != 1.0
                || self.hw.nic_bw != self.hw.a2a_bw
                || self.hw.nic_latency != self.hw.msg_latency)
    }

    /// FLOPs of the attention half of a block for `n` tokens
    /// (qkv + proj GEMMs + 2·T·T·D attention matmuls + adaLN).
    pub fn flops_pre(&self, wl: &Workload) -> f64 {
        let d = self.model.d_model as f64;
        let n = wl.local_tokens() as f64;
        let t = self.model.tokens() as f64;
        let b = wl.local_batch as f64;
        let qkv = 2.0 * n * d * 3.0 * d;
        let proj = 2.0 * n * d * d;
        let attn = 2.0 * 2.0 * b * t * t * d;
        let adaln = 2.0 * b * d * 6.0 * d;
        let router = 2.0 * n * d * self.model.n_experts as f64;
        qkv + proj + attn + adaln + router
    }

    /// FLOPs of the routed experts executed on ONE device per layer:
    /// each device receives `local_tokens * top_k` token-assignments on
    /// average (balanced routing).
    pub fn flops_expert(&self, wl: &Workload) -> f64 {
        let d = self.model.d_model as f64;
        let f = self.model.d_ffn as f64;
        let assignments = wl.local_tokens() as f64 * self.model.top_k as f64;
        2.0 * assignments * (d * f + f * d)
    }

    /// FLOPs of shared experts + residual on the local shard.
    pub fn flops_post(&self, wl: &Workload) -> f64 {
        let d = self.model.d_model as f64;
        let f = self.model.d_ffn as f64;
        let n = wl.local_tokens() as f64;
        2.0 * n * self.model.n_shared as f64 * (d * f + f * d) + 4.0 * n * d
    }

    /// Bytes one device contributes to a single all-to-all (dispatch or
    /// combine): the crossing rows ([`CostModel::a2a_rows`]) at width D
    /// and serving precision.
    pub fn a2a_bytes(&self, wl: &Workload) -> f64 {
        self.a2a_rows(wl) * self.model.d_model as f64 * ELEM_BYTES
    }

    /// Token-rows one device contributes to a single all-to-all that
    /// actually cross the wire (`local_tokens · top_k` routed rows, of
    /// which `(devices-1)/devices` leave the device).
    pub fn a2a_rows(&self, wl: &Workload) -> f64 {
        let cross = (wl.devices - 1) as f64 / wl.devices as f64;
        wl.local_tokens() as f64 * self.model.top_k as f64 * cross
    }

    /// Bytes one device contributes to a single all-to-all after the
    /// residual codec, with `fresh_frac` of the rows actually travelling
    /// (conditional communication throttles the rest). `None` prices the
    /// dense payload — identical to [`CostModel::a2a_bytes`] at
    /// `fresh_frac = 1.0`. The per-device payload is treated as one
    /// encoded block (one per-channel scale vector per collective).
    pub fn a2a_wire_bytes(&self, wl: &Workload, codec: CompressionCodec, fresh_frac: f64) -> f64 {
        let rows = self.a2a_rows(wl) * fresh_frac;
        let d = self.model.d_model;
        match compress::build(codec) {
            None => rows * d as f64 * ELEM_BYTES,
            Some(c) => c.wire_bytes(rows, d, ELEM_BYTES),
        }
    }

    /// α+β-style codec overhead for one all-to-all: fixed encode+decode
    /// launch cost plus the raw payload streamed through the profile's
    /// fused quantize/sparsify throughput (`codec_bw`). Zero when
    /// compression is off; the *identity* codec pays the overhead
    /// without saving bytes, which is exactly why it is the baseline.
    pub fn t_codec(&self, wl: &Workload, codec: CompressionCodec, fresh_frac: f64) -> f64 {
        if codec == CompressionCodec::None {
            return 0.0;
        }
        let raw = self.a2a_rows(wl) * fresh_frac * self.model.d_model as f64 * ELEM_BYTES;
        0.5 * self.hw.coll_overhead + raw / self.hw.codec_bw
    }

    /// All-to-all latency for `bytes` per device. On the flat topology
    /// all traffic funnels through the PCIe host bridge, so effective
    /// per-device bandwidth is `a2a_bw / devices` (this is what makes
    /// 8-GPU shares exceed 4-GPU shares in Table 5). On a hierarchical
    /// topology the payload splits into intra- and inter-node components
    /// at the balanced-routing node-crossing fraction
    /// ([`Topology::inter_frac`]) and each component is priced on its
    /// own fabric ([`CostModel::t_a2a_split`]). `devices == 0` is a
    /// degenerate no-op collective: zero cost, no launch.
    pub fn t_a2a(&self, bytes: f64, devices: usize) -> f64 {
        self.t_a2a_with(bytes, devices, 1.0)
    }

    /// [`CostModel::t_a2a`] with the inter-node byte share scaled by
    /// `inter_scale` — how a topology-aware placement's MEASURED
    /// node-crossing fraction (relative to the contiguous baseline)
    /// enters the virtual-time schedules (`DiceOptions::a2a_inter_scale`,
    /// the node-level analogue of `a2a_cross_scale`). `inter_scale = 1`
    /// is exactly `t_a2a`.
    pub fn t_a2a_with(&self, bytes: f64, devices: usize, inter_scale: f64) -> f64 {
        if devices == 0 {
            return 0.0;
        }
        if !self.hierarchical(devices) {
            return self.hw.coll_overhead
                + self.hw.msg_latency * (devices - 1) as f64
                + bytes * devices as f64 / self.hw.a2a_bw;
        }
        let inter = (bytes * self.topo.inter_frac(devices) * inter_scale).min(bytes);
        self.t_a2a_split(bytes - inter, inter, devices)
    }

    /// All-to-all latency from an explicit intra-/inter-node payload
    /// split (bytes per device). The intra component funnels through the
    /// host bridge exactly as the flat model; the inter component pays
    /// NIC latency per remote peer and streams through the NIC at
    /// `nic_bw / oversub` — striped across `node_size` parallel rails on
    /// the rail-optimized topology.
    pub fn t_a2a_split(&self, intra_bytes: f64, inter_bytes: f64, devices: usize) -> f64 {
        if devices == 0 {
            return 0.0;
        }
        let size0 = self.topo.max_node_size(devices);
        let rails = if self.topo.kind == TopologyKind::Rail {
            size0 as f64
        } else {
            1.0
        };
        self.hw.coll_overhead
            + self.hw.msg_latency * (size0 - 1) as f64
            + self.hw.nic_latency * (devices - size0) as f64
            + intra_bytes * devices as f64 / self.hw.a2a_bw
            + inter_bytes * devices as f64 * self.topo.oversub / (self.hw.nic_bw * rails)
    }

    /// Point-to-point transfer latency (intra-node fabric).
    pub fn t_p2p(&self, bytes: f64) -> f64 {
        self.hw.msg_latency + bytes / self.hw.link_bw
    }

    /// Point-to-point transfer latency across the inter-node path: NIC
    /// message latency, NIC bandwidth, oversubscription applied.
    pub fn t_p2p_inter(&self, bytes: f64) -> f64 {
        self.hw.nic_latency + bytes * self.topo.oversub / self.hw.nic_bw
    }

    /// Placement-rebalance migration latency (DESIGN.md §9): the moved
    /// experts' weights travel point-to-point between the old and new
    /// owner at f16 serving precision, as one bulk transfer. Zero moves
    /// cost zero (no α term — nothing is launched). All moves are priced
    /// intra-node; topology-aware callers that know the node-crossing
    /// split use [`CostModel::t_migrate_split`] instead.
    pub fn t_migrate(&self, moved_experts: usize) -> f64 {
        self.t_migrate_split(moved_experts, 0)
    }

    /// Migration latency with the moves split into intra-node and
    /// cross-node counts ([`crate::moe::Placement::moved_split`]): the
    /// intra bulk goes over the local fabric, the cross-node bulk over
    /// the NIC — strictly slower per expert on every shipped profile,
    /// which is what makes the rebalancer prefer intra-node swaps.
    pub fn t_migrate_split(&self, intra_moves: usize, inter_moves: usize) -> f64 {
        let eb = self.model.expert_param_bytes() as f64;
        let mut t = 0.0;
        if intra_moves > 0 {
            t += self.t_p2p(intra_moves as f64 * eb);
        }
        if inter_moves > 0 {
            t += self.t_p2p_inter(inter_moves as f64 * eb);
        }
        t
    }

    /// Expert copies a per-device parameter-memory budget of
    /// `budget_bytes` holds under this model — the slot capacity the
    /// replication policy and the per-device
    /// `placement::replicate::ExpertCache` enforce (DESIGN.md §15).
    /// Delegates to [`crate::config::ModelConfig::expert_slots`].
    pub fn expert_slots(&self, budget_bytes: usize) -> usize {
        self.model.expert_slots(budget_bytes)
    }

    /// Expert-cache fetch-on-miss latency (DESIGN.md §15): a miss
    /// re-fetches one expert's full weights from the nearest resident
    /// copy, which is EXACTLY a migration copy over the same fabric —
    /// this is [`CostModel::t_migrate_split`] by definition, named so
    /// call sites read as the cache pricing contract they implement.
    pub fn t_fetch_split(&self, intra_fetches: usize, inter_fetches: usize) -> f64 {
        self.t_migrate_split(intra_fetches, inter_fetches)
    }

    /// All-to-all latency priced from a MEASURED engine dispatch plan
    /// rather than the analytic balanced-routing payload: the crossing
    /// bytes come from [`crate::moe::DispatchPlan::cross_bytes`], whose
    /// per-plan memo means pricing both collectives of every layer from
    /// one plan scans the entries once, not once per priced collective.
    ///
    /// This is the moe↔netsim pricing contract: `moe` decides *which*
    /// rows cross (source device vs. the placement's owner map — so a
    /// rebalanced [`crate::moe::Placement`] changes the payload, which
    /// is why the memo keys on the map fingerprint), and this model
    /// decides *what the bytes cost* (α+β under host-bridge contention).
    /// The analytic [`CostModel::a2a_bytes`] path assumes balanced
    /// routing with a `(D-1)/D` crossing fraction; placement policies
    /// feed their measured fraction into the virtual-time schedules via
    /// `DiceOptions::a2a_cross_scale` instead (DESIGN.md §9).
    /// On a hierarchical topology the crossing bytes come split by node
    /// boundary ([`crate::moe::DispatchPlan::cross_bytes_split`]) and
    /// each component is priced on its own fabric; the flat path is
    /// untouched (bit-identical).
    pub fn t_a2a_measured(
        &self,
        plan: &crate::moe::DispatchPlan,
        placement: &crate::moe::Placement,
    ) -> f64 {
        if self.hierarchical(placement.devices) {
            let (intra, inter) =
                plan.cross_bytes_split(placement, self.topo, self.model.d_model, ELEM_BYTES as usize);
            return self.t_a2a_split(intra as f64, inter as f64, placement.devices);
        }
        let bytes = plan.cross_bytes(placement, self.model.d_model, ELEM_BYTES as usize) as f64;
        self.t_a2a(bytes, placement.devices)
    }

    /// Effective compute time: small batches under-utilise the GPU, so
    /// throughput ramps with the resident token count and saturates at
    /// the profile's peak (this is why the paper's a2a share RISES with
    /// batch — comm scales linearly while compute scales sublinearly).
    pub fn t_compute_at(&self, flops: f64, local_tokens: usize) -> f64 {
        let n = local_tokens as f64;
        let util = n / (n + self.hw.sat_tokens);
        flops / (self.hw.flops * util)
    }

    /// Compute time at full utilisation (saturated batch).
    pub fn t_compute(&self, flops: f64) -> f64 {
        flops / self.hw.flops
    }

    /// All per-layer costs for a workload.
    pub fn layer_costs(&self, wl: &Workload) -> LayerCosts {
        let bytes = self.a2a_bytes(wl);
        let n = wl.local_tokens();
        LayerCosts {
            t_pre: self.t_compute_at(self.flops_pre(wl), n),
            t_expert: self.t_compute_at(self.flops_expert(wl), n),
            t_post: self.t_compute_at(self.flops_post(wl), n),
            t_a2a: self.t_a2a(bytes, wl.devices),
            a2a_bytes: bytes,
        }
    }

    /// Embed + cond + final compute (once per step, replicated).
    pub fn t_affix(&self, wl: &Workload) -> f64 {
        let d = self.model.d_model as f64;
        let n = wl.local_tokens() as f64;
        let pd = self.model.patch_dim() as f64;
        self.t_compute_at(
            2.0 * n * pd * d + 2.0 * n * d * pd + 4.0 * wl.local_batch as f64 * d * d,
            wl.local_tokens(),
        )
    }

    // ----------------------------------------------------------------
    // Memory model (bytes per device)
    // ----------------------------------------------------------------

    /// Peak activation working set per device (a few [B,T,D]-sized live
    /// tensors during a block).
    pub fn activation_bytes(&self, wl: &Workload) -> f64 {
        let live_tensors = 6.0;
        wl.local_tokens() as f64 * self.model.d_model as f64 * ELEM_BYTES * live_tensors
    }

    /// Staleness-buffer bytes per device for a strategy that persists
    /// `buffers_per_layer` activation-sized buffers across steps
    /// (displaced EP: 2 = dispatch + combine; interweaved: 1 = combine
    /// only — the paper's "half the buffer size").
    pub fn staleness_buffer_bytes(&self, wl: &Workload, buffers_per_layer: f64) -> f64 {
        let per_layer =
            wl.local_tokens() as f64 * self.model.top_k as f64 * self.model.d_model as f64 * ELEM_BYTES;
        buffers_per_layer * self.model.n_layers as f64 * per_layer
    }

    /// DistriFusion staleness buffers: every device keeps full-sequence
    /// copies of each asynchronously-exchanged tensor per layer —
    /// DistriFusion buffers the boundary activations of every comm op
    /// (block input, K, V and their in-flight send/recv doubles),
    /// ~12 full-sequence tensors per layer at fp16. This is what drives
    /// the paper's DistriFusion OOM at XL batch >= 16.
    pub fn dfu_buffer_bytes(&self, wl: &Workload) -> f64 {
        const BUFS_PER_LAYER: f64 = 12.0; // (input + K + V) x (live + send + recv)
        BUFS_PER_LAYER
            * self.model.n_layers as f64
            * wl.global_batch() as f64
            * self.model.tokens() as f64
            * self.model.d_model as f64
            * ELEM_BYTES
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{hardware_profile, model_preset};

    fn xl8(batch: usize) -> (CostModel, Workload) {
        let cm = CostModel::new(
            model_preset("xl").unwrap(),
            hardware_profile("rtx4090_pcie").unwrap(),
        );
        let tokens = cm.model.tokens();
        (
            cm,
            Workload {
                local_batch: batch,
                devices: 8,
                tokens,
            },
        )
    }

    #[test]
    fn a2a_dominates_at_xl_scale() {
        // Paper Table 5: a2a share 75-79% on 8 GPUs for XL. At the level
        // of a single layer that means 2·t_a2a >> compute.
        let (cm, wl) = xl8(8);
        let c = cm.layer_costs(&wl);
        let comm = 2.0 * c.t_a2a;
        let comp = c.t_pre + c.t_expert + c.t_post;
        let share = comm / (comm + comp);
        assert!(share > 0.6 && share < 0.9, "a2a share {share}");
    }

    #[test]
    fn a2a_share_grows_with_batch() {
        let shares: Vec<f64> = [4, 8, 16, 32]
            .iter()
            .map(|&b| {
                let (cm, wl) = xl8(b);
                let c = cm.layer_costs(&wl);
                2.0 * c.t_a2a / (2.0 * c.t_a2a + c.t_pre + c.t_expert + c.t_post)
            })
            .collect();
        for w in shares.windows(2) {
            assert!(w[1] >= w[0] - 1e-9, "{shares:?}");
        }
    }

    #[test]
    fn bytes_scale_linearly_with_batch() {
        let (cm, wl4) = xl8(4);
        let (_, wl8) = xl8(8);
        let r = cm.a2a_bytes(&wl8) / cm.a2a_bytes(&wl4);
        assert!((r - 2.0).abs() < 1e-9);
    }

    #[test]
    fn interweaved_buffer_is_half_displaced() {
        let (cm, wl) = xl8(8);
        let disp = cm.staleness_buffer_bytes(&wl, 2.0);
        let intw = cm.staleness_buffer_bytes(&wl, 1.0);
        assert!((disp / intw - 2.0).abs() < 1e-9);
    }

    #[test]
    fn dfu_ooms_on_g_but_ep_fits() {
        let g = model_preset("g").unwrap();
        let hw = hardware_profile("rtx4090_pcie").unwrap();
        // DistriFusion replicates the full model: > 24 GB => OOM.
        assert!(g.param_bytes() > hw.mem_bytes);
        // EP on 8 devices shards the experts: fits.
        assert!(g.param_bytes_per_device_ep(8) < hw.mem_bytes);
    }

    #[test]
    fn codec_wire_bytes_ordering_and_consistency() {
        let (cm, wl) = xl8(8);
        let dense = cm.a2a_wire_bytes(&wl, CompressionCodec::None, 1.0);
        assert!((dense - cm.a2a_bytes(&wl)).abs() < 1e-6, "None == dense payload");
        let id = cm.a2a_wire_bytes(&wl, CompressionCodec::Identity, 1.0);
        assert!((id - dense).abs() < 1e-6, "identity saves nothing");
        let int8 = cm.a2a_wire_bytes(&wl, CompressionCodec::Int8, 1.0);
        let topk = cm.a2a_wire_bytes(&wl, CompressionCodec::TopK, 1.0);
        assert!(int8 < dense, "int8 {int8} vs dense {dense}");
        assert!(topk < int8, "topk {topk} vs int8 {int8}");
        // at f16 serving precision int8 halves the payload (+ scales)
        assert!(int8 / dense > 0.45 && int8 / dense < 0.55, "{}", int8 / dense);
        // throttled rows compress proportionally
        let int8_cc = cm.a2a_wire_bytes(&wl, CompressionCodec::Int8, 0.75);
        assert!(int8_cc < int8);
    }

    #[test]
    fn codec_overhead_is_alpha_beta() {
        let (cm, wl) = xl8(8);
        assert_eq!(cm.t_codec(&wl, CompressionCodec::None, 1.0), 0.0);
        let t1 = cm.t_codec(&wl, CompressionCodec::Int8, 1.0);
        let t2 = cm.t_codec(&wl, CompressionCodec::Int8, 0.5);
        // α survives at small payloads, β scales with the raw bytes
        assert!(t1 > t2 && t2 > 0.5 * cm.hw.coll_overhead);
        // the overhead must stay well under the a2a it shortens,
        // otherwise compression could never win
        let c = cm.layer_costs(&wl);
        assert!(t1 < 0.1 * c.t_a2a, "codec {t1} vs a2a {}", c.t_a2a);
    }

    #[test]
    fn measured_plan_pricing_matches_direct_formula() {
        use crate::moe::{DispatchPlan, Placement, RoutingTable};
        use crate::tensor::Tensor;
        let cm = CostModel::new(
            model_preset("xl").unwrap(),
            hardware_profile("rtx4090_pcie").unwrap(),
        );
        // 8 tokens on 2 devices, every token to both of 2 experts
        let probs = Tensor::from_vec(&[8, 2], vec![0.6, 0.4].repeat(8));
        let rt = RoutingTable::from_probs(&probs, 2);
        let plan = DispatchPlan::build(&rt, 4);
        let p = Placement::new(2, 2);
        let direct = cm.t_a2a(
            plan.cross_bytes(&p, cm.model.d_model, ELEM_BYTES as usize) as f64,
            2,
        );
        let measured = cm.t_a2a_measured(&plan, &p);
        assert_eq!(measured, direct);
        // second call serves the byte count from the plan's memo
        assert_eq!(cm.t_a2a_measured(&plan, &p), measured);
        assert!(measured > 0.0);
    }

    #[test]
    fn migration_pricing_scales_with_moved_experts() {
        let (cm, wl) = xl8(8);
        assert_eq!(cm.t_migrate(0), 0.0, "no moves, no launch");
        let one = cm.t_migrate(1);
        let four = cm.t_migrate(4);
        assert!(one > 0.0);
        // one bulk transfer: α paid once, β scales with the payload
        assert!(four > 3.0 * one / 2.0 && four < 4.0 * one);
        // a handful of moved experts must cost less than one full
        // 50-step run's all-to-all time, or rebalancing could never pay
        let c = cm.layer_costs(&wl);
        assert!(four < 2.0 * c.t_a2a * cm.model.n_layers as f64 * 50.0);
    }

    #[test]
    fn zero_devices_collective_is_free() {
        // the (devices - 1) α term used to underflow at devices == 0;
        // a no-op collective costs nothing and launches nothing.
        let (cm, _) = xl8(8);
        assert_eq!(cm.t_a2a(1.0e6, 0), 0.0);
        assert_eq!(cm.t_a2a_with(1.0e6, 0, 1.0), 0.0);
        assert_eq!(cm.t_a2a_split(1.0e6, 1.0e6, 0), 0.0);
        let hier = cm.clone().with_topology(Topology::multinode(4));
        assert_eq!(hier.t_a2a(1.0e6, 0), 0.0);
    }

    #[test]
    fn uniform_hierarchy_collapses_to_flat_bit_exact() {
        // property (a): when the inter-node path is indistinguishable
        // from the intra-node fabric (same bandwidth, same latency, no
        // oversubscription), hierarchical pricing IS the flat price —
        // bit-exact, not approximately (the split path is not taken).
        let (flat, _) = xl8(8);
        let mut hw = flat.hw.clone();
        hw.nic_bw = hw.a2a_bw;
        hw.nic_latency = hw.msg_latency;
        let uniform = CostModel::new(flat.model.clone(), hw)
            .with_topology(Topology::multinode(4));
        for devices in [1usize, 2, 3, 8, 64] {
            for bytes in [0.0, 1.0, 1.7e6, 3.3e9] {
                assert_eq!(
                    uniform.t_a2a(bytes, devices),
                    flat.t_a2a(bytes, devices),
                    "devices {devices} bytes {bytes}"
                );
            }
        }
        // fattree:1.0 with a uniform NIC is equally degenerate
        let ft = CostModel::new(uniform.model.clone(), uniform.hw.clone())
            .with_topology(Topology::fattree(1.0, 4));
        assert_eq!(ft.t_a2a(2.0e6, 16), flat.t_a2a(2.0e6, 16));
    }

    #[test]
    fn one_node_topology_prices_flat_bit_exact() {
        // the acceptance gate's degenerate case: one node == flat, even
        // with a real (slower) NIC configured in the profile.
        let (flat, _) = xl8(8);
        let one = flat.clone().with_topology(Topology::multinode(1));
        for devices in [1usize, 2, 8, 128] {
            for bytes in [0.0, 512.0, 4.2e6] {
                assert_eq!(one.t_a2a(bytes, devices), flat.t_a2a(bytes, devices));
            }
        }
        // ...and any topology collapses when the devices fit one node
        let mn = flat.clone().with_topology(Topology::multinode(0));
        for devices in [1usize, 2, 8] {
            // auto nodes = ceil(d/8): one node up to 8 devices
            assert_eq!(mn.t_a2a(1.0e6, devices), flat.t_a2a(1.0e6, devices));
        }
    }

    #[test]
    fn a2a_monotone_in_oversubscription() {
        // property (b), first half: a fatter oversubscription factor
        // never makes the collective cheaper.
        let (flat, _) = xl8(8);
        let bytes = 2.5e6;
        let mut prev = 0.0;
        for (i, o) in [1.0, 1.5, 2.0, 4.0, 8.0].into_iter().enumerate() {
            let cm = flat.clone().with_topology(Topology::fattree(o, 4));
            let t = cm.t_a2a(bytes, 16);
            assert!(t > 0.0);
            if i > 0 {
                assert!(t >= prev, "oversub {o}: {t} < {prev}");
            }
            prev = t;
        }
    }

    #[test]
    fn a2a_monotone_in_inter_node_byte_share() {
        // property (b), second half: shifting bytes from the intra-node
        // fabric to the NIC never speeds the collective up (the NIC is
        // strictly slower on every shipped profile).
        let (flat, _) = xl8(8);
        let cm = flat.clone().with_topology(Topology::multinode(2));
        let total = 4.0e6;
        let mut prev = -1.0;
        for k in 0..=8 {
            let inter = total * k as f64 / 8.0;
            let t = cm.t_a2a_split(total - inter, inter, 8);
            assert!(t > prev, "share {k}/8: {t} vs {prev}");
            prev = t;
        }
        // the same monotonicity through the inter_scale knob
        let t_half = cm.t_a2a_with(total, 8, 0.5);
        let t_full = cm.t_a2a_with(total, 8, 1.0);
        assert!(t_half < t_full);
        // scale caps at the full payload instead of inventing bytes
        assert_eq!(
            cm.t_a2a_with(total, 8, 1e9),
            cm.t_a2a_split(0.0, total, 8)
        );
        // and the hierarchical price is never below flat at equal bytes
        assert!(cm.t_a2a(total, 8) > flat.t_a2a(total, 8));
    }

    #[test]
    fn cross_node_migration_strictly_costlier() {
        // satellite: a cross-node expert move pays the NIC and must be
        // strictly more expensive than the same move intra-node.
        for name in ["rtx4090_pcie", "rtx3080_pcie", "nvlink"] {
            let cm = CostModel::new(
                model_preset("xl").unwrap(),
                hardware_profile(name).unwrap(),
            )
            .with_topology(Topology::multinode(2));
            let intra = cm.t_migrate_split(1, 0);
            let inter = cm.t_migrate_split(0, 1);
            assert!(inter > intra, "{name}: inter {inter} vs intra {intra}");
            // mixed split = sum of the two bulk transfers
            let both = cm.t_migrate_split(1, 1);
            assert!((both - (intra + inter)).abs() < 1e-12);
            assert_eq!(cm.t_migrate_split(0, 0), 0.0);
        }
        // flat wrapper: everything intra, unchanged pricing
        let (cm, _) = xl8(8);
        for m in [0usize, 1, 4] {
            assert_eq!(cm.t_migrate(m), cm.t_migrate_split(m, 0));
        }
    }

    #[test]
    fn hierarchical_measured_pricing_uses_the_split() {
        use crate::moe::{DispatchPlan, Placement, RoutingTable};
        use crate::tensor::Tensor;
        let topo = Topology::multinode(2);
        let cm = CostModel::new(
            model_preset("xl").unwrap(),
            hardware_profile("rtx4090_pcie").unwrap(),
        )
        .with_topology(topo);
        // 8 tokens on 4 devices, every token to both of 4 experts
        let probs = Tensor::from_vec(&[8, 4], vec![0.4, 0.3, 0.2, 0.1].repeat(8));
        let rt = RoutingTable::from_probs(&probs, 2);
        let plan = DispatchPlan::build(&rt, 2);
        let p = Placement::new(4, 4);
        let (intra, inter) =
            plan.cross_bytes_split(&p, topo, cm.model.d_model, ELEM_BYTES as usize);
        assert!(inter > 0, "skew-free routing must cross nodes here");
        let direct = cm.t_a2a_split(intra as f64, inter as f64, 4);
        assert_eq!(cm.t_a2a_measured(&plan, &p), direct);
        // memoized second call agrees
        assert_eq!(cm.t_a2a_measured(&plan, &p), direct);
        // and costs strictly more than the flat pricing of the same plan
        let flat = CostModel::new(cm.model.clone(), cm.hw.clone());
        assert!(direct > flat.t_a2a_measured(&plan, &p));
    }

    #[test]
    fn nvlink_kills_the_bottleneck() {
        let cm = CostModel::new(
            model_preset("xl").unwrap(),
            hardware_profile("nvlink").unwrap(),
        );
        let wl = Workload {
            local_batch: 8,
            devices: 8,
            tokens: cm.model.tokens(),
        };
        let c = cm.layer_costs(&wl);
        let share = 2.0 * c.t_a2a / (2.0 * c.t_a2a + c.t_pre + c.t_expert + c.t_post);
        assert!(share < 0.45, "nvlink a2a share {share}");
    }
}
