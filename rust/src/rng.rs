//! Deterministic PRNG substrate (no `rand` crate offline).
//!
//! xoshiro256++ — fast, high-quality, and trivially seedable; used for
//! sampling noise, workload arrival processes and the property-test
//! harness. Determinism matters: every experiment in EXPERIMENTS.md is
//! reproducible from its seed.

/// xoshiro256++ generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so that small consecutive seeds give
    /// well-separated states.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng {
            s: [next(), next(), next(), next()],
        }
    }

    /// Next raw 64-bit output of the generator.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn uniform_f32(&mut self) -> f32 {
        self.uniform() as f32
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free bounded draw is overkill here; modulo
        // bias is < 2^-53 for our n.
        (self.uniform() * n as f64) as usize
    }

    /// Standard normal via Box–Muller (one value per call; simple > fast).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.uniform();
            if u1 > 1e-300 {
                let u2 = self.uniform();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Standard-normal draw narrowed to f32.
    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Exponential with the given rate (inter-arrival times for Poisson
    /// processes in the workload generator).
    pub fn exponential(&mut self, rate: f64) -> f64 {
        debug_assert!(rate > 0.0);
        -(1.0 - self.uniform()).ln() / rate
    }

    /// Fill a slice with standard-normal f32s.
    pub fn fill_normal(&mut self, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = self.normal_f32();
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Split off an independent stream (for per-device / per-request RNGs).
    pub fn split(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_seed_sensitive() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(1);
        let mut c = Rng::new(2);
        let xs: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        let zs: Vec<u64> = (0..8).map(|_| c.next_u64()).collect();
        assert_eq!(xs, ys);
        assert_ne!(xs, zs);
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(11);
        let rate = 4.0;
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.exponential(rate)).sum::<f64>() / n as f64;
        assert!((mean - 0.25).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(5);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }
}
