//! Residual all-to-all compression (DESIGN.md §7).
//!
//! DICE reduces *how often* the expert-parallel all-to-alls pay their
//! full blocking cost; this module attacks the orthogonal axis — *how
//! many bytes* each all-to-all moves. Diffusion steps are temporally
//! redundant (the same latent patches iterate), so the delta between
//! the activations dispatched this step and the ones dispatched last
//! step for the same (token, expert) pair is small and highly
//! compressible ("Accelerating Parallel Diffusion Model Serving with
//! Residual Compression", arXiv 2507.17511).
//!
//! The scheme is classic residual coding with error feedback: sender
//! and receiver both hold a per-(token, expert) *reference* row (a
//! [`RefStore`]); the sender encodes `residual = current − reference`,
//! the receiver decodes and reconstructs `reference + decoded`, and
//! **both sides advance the reference to the reconstruction** so the
//! streams never drift apart. Quantization error therefore shows up in
//! the next step's residual and is re-transmitted rather than
//! accumulating.
//!
//! Three codecs implement [`ResidualCodec`]:
//!
//! * [`IdentityCodec`] — dense f32 round trip, zero loss, zero saving.
//!   The baseline every other codec is compared against.
//! * [`Int8Codec`] — symmetric int8 quantization with **per-channel**
//!   scales (one f32 scale per model channel, shared by every row of
//!   the block). Absolute error is bounded by half a quantization step
//!   per channel.
//! * [`TopKCodec`] — per-row magnitude sparsification: only the
//!   `keep` largest-|residual| channels of each row travel (value +
//!   u16 channel index); everything else decodes to zero and is
//!   retried next step via the error feedback.
//!
//! The engine applies codecs to the rows that actually cross devices
//! (`coordinator::engine::Engine`); the analytic cost model prices the
//! same byte math at the paper's scales (`netsim::CostModel`); both are
//! selected by the `CompressionCodec` config knob (`--compress`).

use crate::config::CompressionCodec;
use crate::tensor::Tensor;

/// Default kept-channel fraction of [`TopKCodec`] (1 in 8 channels).
pub const TOPK_KEEP_FRAC: f64 = 0.125;

/// Wire bytes of one top-k entry: f32 value + u16 channel index.
const TOPK_ENTRY_BYTES: usize = 6;

/// An encoded residual block: the wire payload for one all-to-all
/// destination, plus byte accounting. Self-describing — [`Encoded::decode`]
/// reconstructs the dense residual without further codec state.
#[derive(Debug, Clone)]
pub struct Encoded {
    /// Bytes this block occupies on the wire (payload + side info such
    /// as per-channel scales or sparse indices).
    pub wire_bytes: usize,
    /// Dense f32 bytes the block replaced (`rows × d × 4`).
    pub raw_bytes: usize,
    rows: usize,
    d: usize,
    payload: Payload,
}

#[derive(Debug, Clone)]
enum Payload {
    /// Dense f32 residual values.
    Dense(Vec<f32>),
    /// Per-channel scales + row-major int8 codes.
    Int8 { scales: Vec<f32>, q: Vec<i8> },
    /// Per-row sparse entries: `kept` (channel, value) pairs per row.
    TopK { kept: usize, idx: Vec<u16>, vals: Vec<f32> },
}

impl Encoded {
    /// Decode to the dense `[rows, d]` residual the receiver reconstructs.
    pub fn decode(&self) -> Tensor {
        let mut out = Tensor::zeros(&[self.rows, self.d]);
        match &self.payload {
            Payload::Dense(v) => out.data_mut().copy_from_slice(v),
            Payload::Int8 { scales, q } => {
                let kern = crate::linalg::simd::active();
                let d = scales.len();
                for r in 0..self.rows {
                    kern.dequantize_row(&q[r * d..(r + 1) * d], scales, out.row_mut(r));
                }
            }
            Payload::TopK { kept, idx, vals } => {
                for r in 0..self.rows {
                    let row = out.row_mut(r);
                    for j in 0..*kept {
                        row[idx[r * kept + j] as usize] = vals[r * kept + j];
                    }
                }
            }
        }
        out
    }
}

/// A residual codec: encodes the delta between the activations
/// dispatched this step and the reference both endpoints share.
///
/// # Examples
///
/// ```
/// use dice::compress::{Int8Codec, ResidualCodec};
/// use dice::tensor::Tensor;
///
/// let residual = Tensor::from_vec(&[2, 3], vec![0.5, -1.0, 0.0, 0.25, 1.0, -0.5]);
/// let codec = Int8Codec;
/// let enc = codec.encode(&residual);
/// assert!(enc.wire_bytes < enc.raw_bytes, "int8 must shrink the block");
/// let decoded = enc.decode();
/// // error bounded by half a quantization step per channel
/// assert!(residual.max_abs_diff(&decoded).unwrap() <= 0.5 * (1.0 / 127.0) + 1e-6);
/// ```
pub trait ResidualCodec {
    /// Canonical codec name (matches `CompressionCodec::name`).
    fn name(&self) -> &'static str;

    /// Encode an `[rows, d]` residual block.
    fn encode(&self, residual: &Tensor) -> Encoded;

    /// Analytic wire bytes for a block of `rows` tokens of width `d` at
    /// `elem_bytes` per raw element. Fractional `rows` are allowed (the
    /// cost model prices expected payloads); at `elem_bytes = 4.0` and
    /// integral `rows` this matches [`ResidualCodec::encode`] exactly.
    fn wire_bytes(&self, rows: f64, d: usize, elem_bytes: f64) -> f64;
}

/// Lossless dense baseline: the residual travels as-is.
#[derive(Debug, Clone, Copy, Default)]
pub struct IdentityCodec;

impl ResidualCodec for IdentityCodec {
    fn name(&self) -> &'static str {
        "identity"
    }

    fn encode(&self, residual: &Tensor) -> Encoded {
        let (rows, d) = residual.rows();
        let raw = rows * d * 4;
        Encoded {
            wire_bytes: raw,
            raw_bytes: raw,
            rows,
            d,
            payload: Payload::Dense(residual.data().to_vec()),
        }
    }

    fn wire_bytes(&self, rows: f64, d: usize, elem_bytes: f64) -> f64 {
        rows * d as f64 * elem_bytes
    }
}

/// Symmetric int8 residual quantization with per-channel scales: for
/// each model channel `c`, `scale[c] = max_rows |r[·,c]| / 127`, codes
/// are `round(r / scale)`. Decoded error is ≤ `scale[c] / 2`.
#[derive(Debug, Clone, Copy, Default)]
pub struct Int8Codec;

impl ResidualCodec for Int8Codec {
    fn name(&self) -> &'static str {
        "int8"
    }

    fn encode(&self, residual: &Tensor) -> Encoded {
        let (rows, d) = residual.rows();
        // both sweeps run on the runtime-dispatched SIMD kernel
        // (DESIGN.md §12); every backend reproduces the scalar
        // max/round/clamp semantics bit-exactly, wire bytes included
        let kern = crate::linalg::simd::active();
        let mut scales = vec![0.0f32; d];
        for r in 0..rows {
            kern.max_abs_fold(&mut scales, residual.row(r));
        }
        for s in scales.iter_mut() {
            *s /= 127.0;
        }
        let mut q = vec![0i8; rows * d];
        for r in 0..rows {
            kern.quantize_row(residual.row(r), &scales, &mut q[r * d..(r + 1) * d]);
        }
        Encoded {
            wire_bytes: rows * d + d * 4,
            raw_bytes: rows * d * 4,
            rows,
            d,
            payload: Payload::Int8 { scales, q },
        }
    }

    fn wire_bytes(&self, rows: f64, d: usize, elem_bytes: f64) -> f64 {
        // 1 byte per element + one scale per channel at raw precision.
        rows * d as f64 + d as f64 * elem_bytes
    }
}

/// Per-row top-k residual sparsification: the `keep` largest-magnitude
/// channels of each row travel exactly (value + u16 index), the rest
/// decode to zero and are recovered by the error feedback next step.
#[derive(Debug, Clone, Copy)]
pub struct TopKCodec {
    keep_frac: f64,
}

impl TopKCodec {
    /// Codec keeping `keep_frac` of each row's channels (at least one).
    pub fn new(keep_frac: f64) -> TopKCodec {
        assert!(keep_frac > 0.0 && keep_frac <= 1.0, "keep_frac {keep_frac}");
        TopKCodec { keep_frac }
    }

    /// Channels kept per row of width `d`.
    pub fn kept(&self, d: usize) -> usize {
        ((d as f64 * self.keep_frac).ceil() as usize).clamp(1, d)
    }
}

impl Default for TopKCodec {
    fn default() -> TopKCodec {
        TopKCodec::new(TOPK_KEEP_FRAC)
    }
}

impl ResidualCodec for TopKCodec {
    fn name(&self) -> &'static str {
        "topk"
    }

    fn encode(&self, residual: &Tensor) -> Encoded {
        let (rows, d) = residual.rows();
        assert!(d <= u16::MAX as usize + 1, "channel index must fit u16");
        let kept = self.kept(d);
        let mut idx = Vec::with_capacity(rows * kept);
        let mut vals = Vec::with_capacity(rows * kept);
        let mut order: Vec<usize> = Vec::with_capacity(d);
        for r in 0..rows {
            let row = residual.row(r);
            order.clear();
            order.extend(0..d);
            // magnitude-descending, index-ascending tie-break (deterministic)
            order.sort_by(|&a, &b| {
                row[b].abs().partial_cmp(&row[a].abs()).unwrap().then(a.cmp(&b))
            });
            let mut top: Vec<usize> = order[..kept].to_vec();
            top.sort_unstable();
            for c in top {
                idx.push(c as u16);
                vals.push(row[c]);
            }
        }
        Encoded {
            wire_bytes: rows * kept * TOPK_ENTRY_BYTES,
            raw_bytes: rows * d * 4,
            rows,
            d,
            payload: Payload::TopK { kept, idx, vals },
        }
    }

    fn wire_bytes(&self, rows: f64, d: usize, elem_bytes: f64) -> f64 {
        // value at raw precision + u16 channel index per kept entry.
        rows * self.kept(d) as f64 * (elem_bytes + 2.0)
    }
}

/// Instantiate the codec a [`CompressionCodec`] config selects
/// (`None` means the compression machinery is bypassed entirely).
pub fn build(codec: CompressionCodec) -> Option<Box<dyn ResidualCodec>> {
    match codec {
        CompressionCodec::None => None,
        CompressionCodec::Identity => Some(Box::new(IdentityCodec)),
        CompressionCodec::Int8 => Some(Box::new(Int8Codec)),
        CompressionCodec::TopK => Some(Box::new(TopKCodec::default())),
    }
}

/// Per-(token, expert) reference rows the residual is taken against.
/// Implemented by `coordinator::buffers::ResidualRefCache` (dispatch
/// side) and `coordinator::condcomm::CondCommCache` (combine side —
/// the cached expert output IS the last transmitted reconstruction).
pub trait RefStore {
    /// The reference row for (token, expert), if one has been stored.
    fn get_ref(&self, token: usize, expert: usize) -> Option<&[f32]>;
    /// Advance the reference to `row` (the RECONSTRUCTED value both
    /// endpoints share after decode).
    fn put_ref(&mut self, token: usize, expert: usize, row: &[f32]);
}

/// Byte/row accounting of codec work (merged into `RunStats`).
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct CodecStats {
    /// Dense f32 bytes the transmitted rows would have cost.
    pub raw_bytes: usize,
    /// Bytes actually on the wire (encoded payloads + cold-start rows).
    pub wire_bytes: usize,
    /// Rows that went through an encode→decode round trip.
    pub coded_rows: usize,
    /// Rows transmitted dense because no reference existed yet.
    pub dense_rows: usize,
    /// Encoded blocks produced.
    pub blocks: usize,
}

impl CodecStats {
    /// Bytes the codec avoided (`raw - wire`; 0 when it expanded).
    pub fn saved_bytes(&self) -> usize {
        self.raw_bytes.saturating_sub(self.wire_bytes)
    }

    /// Accumulate another stage's stats into this one.
    pub fn merge(&mut self, o: &CodecStats) {
        self.raw_bytes += o.raw_bytes;
        self.wire_bytes += o.wire_bytes;
        self.coded_rows += o.coded_rows;
        self.dense_rows += o.dense_rows;
        self.blocks += o.blocks;
    }
}

/// Compress-and-reconstruct one all-to-all block in place.
///
/// `rows[i]` indexes a row of `block` that crosses devices and is keyed
/// by `keys[i] = (token, expert)`. Rows with a reference in `refs` are
/// encoded as one residual block, decoded, and **overwritten with the
/// reconstruction** (what the receiver actually sees); rows without a
/// reference travel dense (cold start). Either way the reference
/// advances to the transmitted value, keeping sender and receiver in
/// lockstep. Rows not listed in `rows` (local to the expert's owner)
/// are untouched — and conditional-communication *reused* entries never
/// reach this function at all, so cached-step tokens skip codec work
/// entirely.
pub fn transcode_block(
    codec: &dyn ResidualCodec,
    block: &mut Tensor,
    rows: &[usize],
    keys: &[(usize, usize)],
    refs: &mut dyn RefStore,
    stats: &mut CodecStats,
) {
    debug_assert_eq!(rows.len(), keys.len());
    if rows.is_empty() {
        return;
    }
    let (_, d) = block.rows();
    // split cold-start rows from codable ones, copying references out
    // (the borrow ends before we advance them below).
    let mut coded: Vec<(usize, (usize, usize), Vec<f32>)> = Vec::new();
    for (&r, &(token, expert)) in rows.iter().zip(keys) {
        match refs.get_ref(token, expert) {
            Some(reference) => coded.push((r, (token, expert), reference.to_vec())),
            None => {
                stats.raw_bytes += d * 4;
                stats.wire_bytes += d * 4;
                stats.dense_rows += 1;
                refs.put_ref(token, expert, block.row(r));
            }
        }
    }
    if coded.is_empty() {
        return;
    }
    let mut residual = Tensor::zeros(&[coded.len(), d]);
    for (i, (r, _, reference)) in coded.iter().enumerate() {
        let dst = residual.row_mut(i);
        for (c, (x, rf)) in block.row(*r).iter().zip(reference).enumerate() {
            dst[c] = x - rf;
        }
    }
    let enc = codec.encode(&residual);
    stats.raw_bytes += enc.raw_bytes;
    stats.wire_bytes += enc.wire_bytes;
    stats.coded_rows += coded.len();
    stats.blocks += 1;
    let decoded = enc.decode();
    for (i, (r, (token, expert), reference)) in coded.iter().enumerate() {
        let row = block.row_mut(*r);
        for (c, (rf, dv)) in reference.iter().zip(decoded.row(i)).enumerate() {
            row[c] = rf + dv;
        }
        refs.put_ref(*token, *expert, block.row(*r));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::CondCommSelector;
    use crate::coordinator::buffers::ResidualRefCache;
    use crate::coordinator::condcomm::{self, CondCommCache};
    use crate::moe::{DispatchPlan, RoutingTable};
    use crate::rng::Rng;
    use crate::testkit::{forall, Gen};

    fn random_block(g: &mut Gen, rows: usize, d: usize) -> Tensor {
        Tensor::from_vec(&[rows, d], (0..rows * d).map(|_| g.f32_normal()).collect())
    }

    #[test]
    fn identity_is_lossless_and_full_size() {
        forall(32, 0xC0DEC, |g| {
            let (rows, d) = (g.usize_in(1..9), g.usize_in(1..33));
            let r = random_block(g, rows, d);
            let enc = IdentityCodec.encode(&r);
            assert_eq!(enc.wire_bytes, rows * d * 4);
            assert_eq!(enc.decode(), r);
        });
    }

    #[test]
    fn int8_error_bounded_by_half_scale_per_channel() {
        forall(32, 0xC0DEC + 1, |g| {
            let (rows, d) = (g.usize_in(1..9), g.usize_in(1..33));
            let r = random_block(g, rows, d);
            let enc = Int8Codec.encode(&r);
            assert_eq!(enc.wire_bytes, rows * d + d * 4);
            let dec = enc.decode();
            // recompute the per-channel scale the codec used
            for c in 0..d {
                let maxabs = (0..rows).map(|i| r.row(i)[c].abs()).fold(0.0f32, f32::max);
                let scale = maxabs / 127.0;
                for i in 0..rows {
                    let err = (r.row(i)[c] - dec.row(i)[c]).abs();
                    assert!(err <= 0.5 * scale + 1e-6, "err {err} scale {scale}");
                }
            }
        });
    }

    #[test]
    fn int8_zero_residual_roundtrips_exactly() {
        let z = Tensor::zeros(&[3, 5]);
        assert_eq!(Int8Codec.encode(&z).decode(), z);
    }

    #[test]
    fn topk_preserves_the_k_largest_and_zeros_the_rest() {
        let codec = TopKCodec::new(0.25); // keep 2 of 8
        let r = Tensor::from_vec(
            &[1, 8],
            vec![0.1, -3.0, 0.2, 0.05, 2.5, -0.3, 0.0, 0.15],
        );
        let enc = codec.encode(&r);
        assert_eq!(enc.wire_bytes, 2 * TOPK_ENTRY_BYTES);
        let dec = enc.decode();
        assert_eq!(
            dec.data(),
            &[0.0, -3.0, 0.0, 0.0, 2.5, 0.0, 0.0, 0.0],
            "only the two largest-|residual| channels survive"
        );
    }

    #[test]
    fn topk_property_keeps_largest_magnitudes() {
        forall(32, 0xC0DEC + 2, |g| {
            let (rows, d) = (g.usize_in(1..6), g.usize_in(4..40));
            let codec = TopKCodec::default();
            let kept = codec.kept(d);
            let r = random_block(g, rows, d);
            let dec = codec.encode(&r).decode();
            for i in 0..rows {
                let row = r.row(i);
                let drow = dec.row(i);
                let min_kept = drow
                    .iter()
                    .zip(row)
                    .filter(|(dv, _)| **dv != 0.0)
                    .map(|(_, v)| v.abs())
                    .fold(f32::INFINITY, f32::min);
                let n_kept = drow.iter().filter(|v| **v != 0.0).count();
                assert!(n_kept <= kept);
                for (dv, v) in drow.iter().zip(row) {
                    if *dv != 0.0 {
                        assert_eq!(dv, v, "kept values travel exactly");
                    } else {
                        // anything dropped is no larger than the smallest kept
                        assert!(v.abs() <= min_kept + 1e-6);
                    }
                }
            }
        });
    }

    #[test]
    fn analytic_wire_bytes_match_encode_at_f32() {
        forall(24, 0xC0DEC + 3, |g| {
            let (rows, d) = (g.usize_in(1..9), g.usize_in(2..40));
            let r = random_block(g, rows, d);
            let codecs: Vec<Box<dyn ResidualCodec>> = vec![
                Box::new(IdentityCodec),
                Box::new(Int8Codec),
                Box::new(TopKCodec::default()),
            ];
            for c in codecs {
                let enc = c.encode(&r);
                let analytic = c.wire_bytes(rows as f64, d, 4.0);
                assert!(
                    (analytic - enc.wire_bytes as f64).abs() < 1e-6,
                    "{}: analytic {analytic} vs encoded {}",
                    c.name(),
                    enc.wire_bytes
                );
            }
        });
    }

    #[test]
    fn build_matches_config() {
        assert!(build(CompressionCodec::None).is_none());
        for (cfg, name) in [
            (CompressionCodec::Identity, "identity"),
            (CompressionCodec::Int8, "int8"),
            (CompressionCodec::TopK, "topk"),
        ] {
            assert_eq!(build(cfg).unwrap().name(), name);
        }
    }

    #[test]
    fn transcode_error_feedback_keeps_streams_in_lockstep() {
        // Drive 20 steps of a smoothly-drifting block through int8 and
        // check the reconstruction error stays bounded (error feedback)
        // and the stored reference equals the transmitted block exactly.
        let (rows, d) = (4usize, 16usize);
        let mut rng = Rng::new(7);
        let mut truth = Tensor::zeros(&[rows, d]);
        rng.fill_normal(truth.data_mut());
        let mut refs = ResidualRefCache::new(rows, 1, d);
        let keys: Vec<(usize, usize)> = (0..rows).map(|t| (t, 0)).collect();
        let idx: Vec<usize> = (0..rows).collect();
        for step in 0..20 {
            for v in truth.data_mut() {
                *v += 0.05 * rng.normal_f32();
            }
            let mut block = truth.clone();
            let mut cs = CodecStats::default();
            transcode_block(&Int8Codec, &mut block, &idx, &keys, &mut refs, &mut cs);
            if step == 0 {
                assert_eq!(cs.dense_rows, rows, "cold start is dense");
                assert_eq!(block, truth);
            } else {
                assert_eq!(cs.coded_rows, rows);
                let err = block.rel_l2(&truth).unwrap();
                assert!(err < 0.01, "step {step} err {err}");
            }
            for (t, _) in &keys {
                assert_eq!(refs.get_ref(*t, 0).unwrap(), block.row(*t));
            }
        }
    }

    #[test]
    fn condcomm_reused_entries_skip_codec_work_entirely() {
        // Mirror of the engine's ep_moe decision order: the
        // conditional-communication filter splits entries into fresh vs
        // cache-reused FIRST, and only fresh crossing rows ever reach
        // transcode_block. With LowScore stride 2 at an odd step, every
        // rank>0 entry is served from the cache and the codec must see
        // exactly the rank-0 crossing rows.
        let n_tokens = 8usize;
        let (e, k, d, devices) = (4usize, 2usize, 6usize, 2usize);
        let mut g = Rng::new(11);
        let probs = {
            let mut data = Vec::new();
            for _ in 0..n_tokens {
                let mut row: Vec<f32> = (0..e).map(|_| g.uniform_f32() + 0.01).collect();
                let s: f32 = row.iter().sum();
                row.iter_mut().for_each(|v| *v /= s);
                data.extend(row);
            }
            Tensor::from_vec(&[n_tokens, e], data)
        };
        let rt = RoutingTable::from_probs(&probs, k);
        let plan = DispatchPlan::build(&rt, n_tokens / devices);
        let placement = crate::moe::Placement::new(e, devices);

        let mut cache = CondCommCache::new(n_tokens, e, d);
        // step 0: everything fresh — prime the cache for every entry.
        for entries in &plan.per_expert {
            for en in entries {
                cache.put(en.token, en.expert, &vec![1.0; d]);
            }
        }

        // step 1 (odd): LowScore throttles every rank>0 entry.
        let mut refs = ResidualRefCache::new(n_tokens, e, d);
        let mut cs = CodecStats::default();
        let mut rng = Rng::new(0);
        let mut reused = 0usize;
        let mut expected_coded_or_dense = 0usize;
        for (ei, entries) in plan.per_expert.iter().enumerate() {
            let owner = placement.owner(ei);
            let mut rows = Vec::new();
            let mut keys = Vec::new();
            let mut block_rows = Vec::new();
            for en in entries {
                let fresh =
                    condcomm::is_fresh(CondCommSelector::LowScore, en, 1, 2, &mut rng)
                        || cache.get(en.token, en.expert).is_none();
                if !fresh {
                    reused += 1;
                    continue; // served from cache: no codec work
                }
                if en.src_device != owner {
                    rows.push(block_rows.len());
                    keys.push((en.token, en.expert));
                    expected_coded_or_dense += 1;
                }
                block_rows.push(en.token);
            }
            let mut block = Tensor::from_vec(
                &[block_rows.len().max(1), d],
                vec![0.5; block_rows.len().max(1) * d],
            );
            transcode_block(&Int8Codec, &mut block, &rows, &keys, &mut refs, &mut cs);
        }
        assert!(reused > 0, "stride-2 at an odd step must reuse rank-1 entries");
        assert_eq!(
            cs.coded_rows + cs.dense_rows,
            expected_coded_or_dense,
            "codec work is exactly the fresh crossing rows"
        );
        // every reused entry was rank > 0 and its reference never materialised
        assert_eq!(reused, n_tokens * (k - 1) - plan
            .per_expert
            .iter()
            .flatten()
            .filter(|en| en.rank > 0 && cache.get(en.token, en.expert).is_none())
            .count());
    }
}
