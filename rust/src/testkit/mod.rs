//! Property-testing substrate (`proptest` is unavailable offline).
//!
//! A deterministic generator-driven harness with shrinking-lite: each
//! property runs against N random cases from a seeded [`Rng`]; on failure
//! the case index and seed are reported so the exact case replays, and
//! integer-vector inputs are shrunk by halving/truncation before the
//! panic propagates.
//!
//! Usage (`no_run`: rustdoc test binaries don't inherit the xla rpath):
//! ```no_run
//! use dice::testkit::{forall, Gen};
//! forall(64, 0xD1CE, |g| {
//!     let xs = g.vec_usize(0..50, 1..20);
//!     let mut s = xs.clone();
//!     s.sort();
//!     assert!(s.windows(2).all(|w| w[0] <= w[1]));
//! });
//! ```

use crate::rng::Rng;
use std::ops::Range;

/// Random-case generator handed to properties.
pub struct Gen {
    /// The case-seeded generator (exposed for ad-hoc draws).
    pub rng: Rng,
}

impl Gen {
    /// Uniform `usize` in the half-open range.
    pub fn usize_in(&mut self, r: Range<usize>) -> usize {
        r.start + self.rng.below(r.end - r.start)
    }
    /// Uniform `f32` in `[lo, hi)`.
    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.rng.uniform_f32()
    }
    /// Standard-normal `f32`.
    pub fn f32_normal(&mut self) -> f32 {
        self.rng.normal_f32()
    }
    /// Fair coin flip.
    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }
    /// Vector of uniform `usize` draws; element range × length range.
    pub fn vec_usize(&mut self, each: Range<usize>, len: Range<usize>) -> Vec<usize> {
        let n = self.usize_in(len);
        (0..n).map(|_| self.usize_in(each.clone())).collect()
    }
    /// Vector of standard-normal `f32` draws of random length.
    pub fn vec_f32(&mut self, len: Range<usize>) -> Vec<f32> {
        let n = self.usize_in(len);
        (0..n).map(|_| self.f32_normal()).collect()
    }
    /// A random probability row (nonnegative, sums to 1).
    pub fn prob_row(&mut self, n: usize) -> Vec<f32> {
        let mut v: Vec<f32> = (0..n).map(|_| -self.rng.uniform_f32().max(1e-9).ln()).collect();
        let s: f32 = v.iter().sum();
        for x in v.iter_mut() {
            *x /= s;
        }
        v
    }
}

/// Run `prop` against `cases` generated cases. Panics (with the case
/// seed) on the first failure. Captured state is treated as unwind-safe
/// (properties must not rely on it after a failure anyway).
pub fn forall<F: Fn(&mut Gen)>(cases: usize, seed: u64, prop: F) {
    let mut master = Rng::new(seed);
    for case in 0..cases {
        let case_seed = master.next_u64();
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut g = Gen {
                rng: Rng::new(case_seed),
            };
            prop(&mut g);
        }));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property failed at case {case}/{cases} (replay seed {case_seed:#x}): {msg}"
            );
        }
    }
}

/// Shrinking helper for vector-shaped counterexamples: tries removing
/// halves/elements while `fails` still holds; returns the smallest
/// failing input found.
pub fn shrink_vec<T: Clone, F: Fn(&[T]) -> bool>(input: Vec<T>, fails: F) -> Vec<T> {
    debug_assert!(fails(&input));
    let mut cur = input;
    loop {
        let mut progressed = false;
        // try dropping each half
        if cur.len() >= 2 {
            for (lo, hi) in [(0, cur.len() / 2), (cur.len() / 2, cur.len())] {
                let mut cand = cur.clone();
                cand.drain(lo..hi);
                if !cand.is_empty() && fails(&cand) {
                    cur = cand;
                    progressed = true;
                    break;
                }
            }
        }
        if !progressed && cur.len() > 1 {
            // try dropping single elements
            for i in 0..cur.len() {
                let mut cand = cur.clone();
                cand.remove(i);
                if !cand.is_empty() && fails(&cand) {
                    cur = cand;
                    progressed = true;
                    break;
                }
            }
        }
        if !progressed {
            return cur;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial_property() {
        forall(32, 1, |g| {
            let xs = g.vec_usize(0..100, 0..20);
            let mut s = xs.clone();
            s.sort();
            assert_eq!(s.len(), xs.len());
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn forall_reports_failure() {
        forall(64, 2, |g| {
            let n = g.usize_in(0..100);
            assert!(n < 95, "found {n}");
        });
    }

    #[test]
    fn prob_row_sums_to_one() {
        forall(32, 3, |g| {
            let n = g.usize_in(2..16);
            let p = g.prob_row(n);
            let s: f32 = p.iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
            assert!(p.iter().all(|&x| x >= 0.0));
        });
    }

    #[test]
    fn shrink_finds_minimal_failure() {
        // property: no element is >= 100. counterexample contains 150.
        let input = vec![1, 5, 150, 7, 3, 9];
        let min = shrink_vec(input, |xs| xs.iter().any(|&x| x >= 100));
        assert_eq!(min, vec![150]);
    }

    #[test]
    fn gen_ranges_respected() {
        forall(64, 4, |g| {
            let x = g.usize_in(5..10);
            assert!((5..10).contains(&x));
            let f = g.f32_in(-1.0, 1.0);
            assert!((-1.0..1.0).contains(&f));
        });
    }
}
