//! API-compatible in-tree stub of the `xla_extension` PJRT bindings.
//!
//! The `dice` coordinator executes its AOT-lowered HLO artifacts through
//! a small slice of the PJRT C-API surface (client, buffer, executable,
//! literal). The real bindings link a multi-hundred-megabyte XLA shared
//! object that is not available in the offline build environment, so
//! this crate provides the same *types and signatures* with stubbed
//! execution semantics (DESIGN.md §4):
//!
//! * construction and host-side data movement succeed — clients open,
//!   buffers hold real `f32` payloads, HLO text files are read;
//! * anything that would require the XLA compiler/runtime
//!   ([`PjRtClient::compile`], [`PjRtLoadedExecutable::execute_b`])
//!   returns a descriptive [`Error`].
//!
//! Every simulation-mode code path in `dice` (cost models, virtual-time
//! serving, all paper-scale figures/tables) works against this stub.
//! Real-numerics paths detect missing artifacts up front and degrade
//! with a clean error, so `cargo test` passes on a clean checkout.
//! Swapping in the real bindings is a one-line change in
//! `rust/Cargo.toml` — no `dice` source changes are required.

#![warn(missing_docs)]

use std::fmt;
use std::path::Path;

/// Error surface mirroring the real bindings (a message string).
#[derive(Debug, Clone)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable(op: &str) -> Error {
    Error(format!(
        "{op}: PJRT execution is unavailable in this build — the workspace \
         links the in-tree `xla` stub (crates/xla). Point rust/Cargo.toml \
         at the real xla_extension bindings to execute HLO artifacts."
    ))
}

/// Handle to a PJRT client. The stub client can stage host buffers but
/// cannot compile or execute computations.
#[derive(Debug, Default)]
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    /// Open the CPU client. Always succeeds in the stub (opening a
    /// client allocates no XLA resources).
    pub fn cpu() -> Result<PjRtClient, Error> {
        Ok(PjRtClient { _priv: () })
    }

    /// Compile a computation. Always errors in the stub — compilation
    /// requires the real XLA runtime.
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(unavailable("compile"))
    }

    /// Upload a host `f32` buffer of the given dimensions to the
    /// device. The stub stores the payload host-side so uploads (e.g.
    /// weight staging) succeed and round-trip.
    pub fn buffer_from_host_buffer(
        &self,
        data: &[f32],
        dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer, Error> {
        let n: usize = dims.iter().product();
        if n != data.len() {
            return Err(Error(format!(
                "buffer_from_host_buffer: shape {dims:?} wants {n} elements, got {}",
                data.len()
            )));
        }
        Ok(PjRtBuffer {
            data: data.to_vec(),
            dims: dims.to_vec(),
        })
    }
}

/// An HLO module read from its text form. The stub records the source
/// text verbatim; parsing happens in the real bindings.
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    text: String,
}

impl HloModuleProto {
    /// Read an `*.hlo.txt` artifact. Errors if the file is unreadable.
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<HloModuleProto, Error> {
        std::fs::read_to_string(path.as_ref())
            .map(|text| HloModuleProto { text })
            .map_err(|e| Error(format!("read {}: {e}", path.as_ref().display())))
    }

    /// The HLO text this module was built from.
    pub fn text(&self) -> &str {
        &self.text
    }
}

/// A computation handle wrapping a parsed module.
#[derive(Debug, Clone)]
pub struct XlaComputation {
    _proto: HloModuleProto,
}

impl XlaComputation {
    /// Wrap a parsed HLO module as a computation.
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation {
            _proto: proto.clone(),
        }
    }
}

/// A compiled executable. Not constructible through the stub (compile
/// errors first), so execution is unreachable in practice.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    /// Execute on device buffers. Always errors in the stub.
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(unavailable("execute_b"))
    }
}

/// A device buffer. The stub keeps the payload host-side.
#[derive(Debug, Clone)]
pub struct PjRtBuffer {
    data: Vec<f32>,
    dims: Vec<usize>,
}

impl PjRtBuffer {
    /// Fetch the buffer to a host [`Literal`] (synchronous).
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Ok(Literal {
            data: self.data.clone(),
            dims: self.dims.iter().map(|&d| d as i64).collect(),
            tuple: None,
        })
    }
}

/// A host literal: either an `f32` array or a tuple of literals.
#[derive(Debug, Clone, Default)]
pub struct Literal {
    data: Vec<f32>,
    dims: Vec<i64>,
    tuple: Option<Vec<Literal>>,
}

impl Literal {
    /// Decompose a tuple literal into its elements. Errors when called
    /// on an array literal.
    pub fn to_tuple(self) -> Result<Vec<Literal>, Error> {
        self.tuple
            .ok_or_else(|| Error("to_tuple: not a tuple literal".to_string()))
    }

    /// Shape of an array literal.
    pub fn array_shape(&self) -> Result<ArrayShape, Error> {
        Ok(ArrayShape {
            dims: self.dims.clone(),
        })
    }

    /// The raw `f32` payload of an array literal.
    pub fn to_vec(&self) -> Result<Vec<f32>, Error> {
        Ok(self.data.clone())
    }
}

/// Dimensions of an array literal.
#[derive(Debug, Clone)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    /// The dimension sizes, outermost first.
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_opens_and_buffers_roundtrip() {
        let c = PjRtClient::cpu().unwrap();
        let b = c
            .buffer_from_host_buffer(&[1.0, 2.0, 3.0, 4.0], &[2, 2], None)
            .unwrap();
        let lit = b.to_literal_sync().unwrap();
        assert_eq!(lit.array_shape().unwrap().dims(), &[2, 2]);
        assert_eq!(lit.to_vec().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(lit.to_tuple().is_err());
    }

    #[test]
    fn shape_mismatch_rejected() {
        let c = PjRtClient::cpu().unwrap();
        assert!(c.buffer_from_host_buffer(&[1.0], &[2, 2], None).is_err());
    }

    #[test]
    fn compile_reports_stub() {
        let c = PjRtClient::cpu().unwrap();
        let dir = std::env::temp_dir().join("xla_stub_test.hlo.txt");
        std::fs::write(&dir, "HloModule m").unwrap();
        let proto = HloModuleProto::from_text_file(&dir).unwrap();
        assert!(proto.text().contains("HloModule"));
        let comp = XlaComputation::from_proto(&proto);
        let err = c.compile(&comp).unwrap_err();
        assert!(err.to_string().contains("stub"), "{err}");
        let _ = std::fs::remove_file(&dir);
    }

    #[test]
    fn missing_hlo_file_errors() {
        assert!(HloModuleProto::from_text_file("/nonexistent/x.hlo.txt").is_err());
    }
}
