//! Reproduces Table 4: selective-synchronization (Deep/Shallow/
//! Staggered) and conditional-communication (Low/High/Random) ablations
//! on top of interweaved parallelism.
use dice::cli::Args;
use dice::exp::{quality::ablation_table, write_results, Ctx};

fn main() -> anyhow::Result<()> {
    let a = Args::parse();
    let ctx = Ctx::open()?;
    let samples = a.usize_or("samples", 256);
    let steps = a.usize_or("steps", 50);
    let warmup = a.usize_or("warmup", 4);
    let (t, j) = ablation_table(&ctx, samples, steps, warmup, a.u64_or("seed", 1234))?;
    t.print();
    write_results("table4_ablation", &t.render(), &j)?;
    Ok(())
}
