//! Residual-compression trade-off (DESIGN.md §7): for each codec,
//! bytes-per-A2A reduction vs. the identity baseline, real-numerics
//! reconstruction error on a synthetic diffusion-like trajectory, and
//! the analytic XL-scale step latency. Artifact-free. The driver
//! asserts the headline property (int8 strictly fewer bytes than
//! identity at bounded error) and fails loudly if it regresses.
use dice::cli::Args;
use dice::exp::{compress::tradeoff, write_results};

fn main() -> anyhow::Result<()> {
    let a = Args::parse();
    let (t, j) = tradeoff(
        a.usize_or("tokens", 64),
        a.usize_or("dim", 64),
        a.usize_or("steps", 32),
        a.u64_or("seed", 1234),
    )?;
    t.print();
    write_results("compress_tradeoff", &t.render(), &j)?;
    Ok(())
}
