//! Reproduces Table 1: 50-step quality (FID/sFID/IS/Precision/Recall)
//! for the five methods. `--samples N` / `--steps N` / `--warmup N`.
use dice::cli::Args;
use dice::exp::{quality::quality_table, write_results, Ctx};

fn main() -> anyhow::Result<()> {
    let a = Args::parse();
    let ctx = Ctx::open()?;
    let samples = a.usize_or("samples", 256);
    let steps = a.usize_or("steps", 50);
    let warmup = a.usize_or("warmup", 4);
    let (t, j) = quality_table(
        &ctx,
        &format!("Table 1 — quality at {steps} steps ({samples} samples)"),
        samples, steps, warmup, false, a.u64_or("seed", 1234),
    )?;
    t.print();
    write_results("table1_quality", &t.render(), &j)?;
    Ok(())
}
