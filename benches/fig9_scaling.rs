//! Reproduces Figure 9: batch-size and image-size scaling of latency +
//! memory on 8x RTX 4090 for DiT-MoE-XL and -G.
use dice::cli::Args;
use dice::config::{obj, Json};
use dice::exp::{scaling::scaling, write_results};

fn main() -> anyhow::Result<()> {
    let a = Args::parse();
    let steps = a.usize_or("steps", 50);
    let mut md = String::new();
    let mut payload = Vec::new();
    for model in ["xl", "g"] {
        let (tables, j) = scaling(model, "rtx4090_pcie", steps)?;
        for t in tables {
            t.print();
            md.push_str(&t.render());
        }
        payload.push(obj(vec![("model", Json::Str(model.into())), ("data", j)]));
    }
    write_results("fig9_scaling", &md, &Json::Arr(payload))?;
    Ok(())
}
