//! Reproduces Table 5: all-to-all communication share of synchronous
//! expert parallelism across models x GPU counts x batch sizes.
use dice::exp::{scaling::table5, write_results};

fn main() -> anyhow::Result<()> {
    let (t, j) = table5()?;
    t.print();
    write_results("table5_a2a_pct", &t.render(), &j)?;
    Ok(())
}
