//! Reproduces the Sec. 3 motivation numbers: absolute all-to-all time
//! and share for 50-step synchronous EP on XL / 8 GPUs.
use dice::exp::{scaling::motivation, write_results};

fn main() -> anyhow::Result<()> {
    let (t, j) = motivation()?;
    t.print();
    write_results("motivation_a2a", &t.render(), &j)?;
    Ok(())
}
