//! Perf gate + trajectory recorder (DESIGN.md §8, §10–§11): benches the
//! host engine step (dispatch → expert FFN → combine over the worker
//! pool) serial vs parallel, the `pipeline_overlap` quartet (barriered
//! vs overlapped executor, uniform vs skewed routing), the
//! `multilayer_overlap` pair (the §11 cross-layer window on a 4-layer
//! stack), the simulation sweep fan-out, the placement-policy sweep
//! (three solves + crossing-bytes pricing on a skewed plan, DESIGN.md
//! §9), the `topology_placement` solve (node-aware affinity on a
//! 4-node hierarchy, with a custom trajectory record carrying the
//! flat-vs-multinode inter-node byte split and modeled a2a times,
//! DESIGN.md §13), and the `simd_kernels` pair (scalar oracle vs the
//! detected kernel backend on the expert-FFN GEMM, DESIGN.md §12), the
//! `fleet_serving` cell (the §14 multi-replica burst cell behind the
//! least-loaded router, with a custom trajectory record carrying
//! per-router burst p99 and static-vs-autoscaled replica-seconds), the
//! `expert_replication` cell (the §15 memory-budgeted replication
//! report, with a custom record carrying replicated-vs-single-owner
//! max load, crossing bytes, modeled step time and the expert-cache
//! hit rate), and appends every summary to repo-root
//! `BENCH_engine.json` (JSON lines) — the perf trajectory across PRs.
//! Artifact-free.
//!
//!     cargo bench --bench perf_gate              # full iterations
//!     cargo bench --bench perf_gate -- --check   # CI: few iters +
//!                                                # gate assertions
//!
//! Always asserts bit-exactness of both executors across pool widths
//! and of the detected SIMD backend against the scalar oracle;
//! `--check` additionally asserts (on ≥ 2 cores) that the parallel
//! engine step is no slower than serial, that the OVERLAPPED executor
//! is no slower than the barriered one on the skewed-routing workload,
//! that the detected SIMD backend is no slower than the scalar oracle
//! (thread-independent, so it gates even on one core), that the
//! node-aware placement ships no more inter-node bytes (and no more
//! modeled a2a time) than the node-blind solve, that the least-loaded
//! router beats round-robin on burst p99 and the autoscaled fleet
//! bills fewer replica-seconds than the static one (DESIGN.md §14),
//! and that `BENCH_engine.json` is valid JSON lines.

use std::path::PathBuf;

use dice::benchkit::{self, fmt_secs, Summary, Table};
use dice::cli::Args;
use dice::config::{
    hardware_profile, model_preset, DiceOptions, Json, PipelineMode, PlacementKind, SelectiveSync,
    SimdKind, Strategy,
};
use dice::coordinator::{simulate_sweep_with, HostPipeline, SweepCase};
use dice::exp::fleet as fleet_exp;
use dice::exp::replicate as replicate_exp;
use dice::linalg::{self, simd};
use dice::moe::host::{HostMoeConfig, HostMoeLayer, HostMoeStack};
use dice::moe::{DispatchPlan, RoutingTable};
use dice::netsim::{CostModel, Topology, Workload};
use dice::par::ParPool;
use dice::placement::{build, skewed_probs, RoutingStats};
use dice::rng::Rng;
use dice::server::RouterKind;
use dice::tensor::Tensor;
use dice::workload::node_skewed_probs;

/// Repo root (the bench runs with the package dir `rust/` as cwd).
fn repo_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("rust/ has a parent")
        .to_path_buf()
}

fn main() -> anyhow::Result<()> {
    let a = Args::parse();
    if let Some(t) = a.get("threads") {
        dice::par::set_threads(t.parse()?);
    }
    let check = a.flag("check");
    let (warmup, iters) = if check { (1, 5) } else { (3, 12) };
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let par_threads = ParPool::current().threads().min(cores.max(1)).max(2);

    // --- host engine step: serial vs parallel --------------------------
    let cfg = HostMoeConfig {
        n_experts: 8,
        top_k: 2,
        d_model: 128,
        d_ff: 512,
        devices: 4,
    };
    let layer = HostMoeLayer::synth(cfg, 0xD1CE);
    let n_tokens = a.usize_or("tokens", 512);
    let mut x = Tensor::zeros(&[n_tokens, cfg.d_model]);
    Rng::new(7).fill_normal(x.data_mut());

    let serial_pool = ParPool::new(1);
    let par_pool = ParPool::new(par_threads);
    let s_serial = benchkit::bench("engine_step_serial", warmup, iters, || {
        std::hint::black_box(layer.step(&serial_pool, &x));
    });
    let s_par = benchkit::bench(
        &format!("engine_step_t{par_threads}"),
        warmup,
        iters,
        || {
            std::hint::black_box(layer.step(&par_pool, &x));
        },
    );

    // --- sim sweep fan-out: serial vs parallel -------------------------
    let cm = CostModel::new(model_preset("xl")?, hardware_profile("rtx4090_pcie")?);
    let cases: Vec<SweepCase> = [4usize, 8, 16, 32]
        .iter()
        .flat_map(|&b| {
            [
                (Strategy::SyncEp, DiceOptions::none()),
                (Strategy::DisplacedEp, DiceOptions::none()),
                (Strategy::Interweaved, DiceOptions::dice()),
            ]
            .into_iter()
            .map(move |(strategy, opts)| SweepCase {
                wl: Workload {
                    local_batch: b,
                    devices: 8,
                    tokens: 256,
                },
                strategy,
                opts,
                steps: 20,
            })
        })
        .collect();
    let w_serial = benchkit::bench("sim_sweep_serial", warmup, iters, || {
        std::hint::black_box(simulate_sweep_with(&serial_pool, &cm, &cases));
    });
    let w_par = benchkit::bench(
        &format!("sim_sweep_t{par_threads}"),
        warmup,
        iters,
        || {
            std::hint::black_box(simulate_sweep_with(&par_pool, &cm, &cases));
        },
    );

    // --- pipeline overlap: barriered vs overlapped executor ------------
    // (DESIGN.md §10) — uniform routing from the layer's own router,
    // and the seeded skewed routing (one hot expert) where dynamic
    // row-split scheduling must not lose to the static-chunk barriers.
    let skew_probs = skewed_probs(n_tokens, cfg.n_experts, cfg.devices, 0xBEEF);
    let skew_rt = RoutingTable::from_probs(&skew_probs, cfg.top_k);
    let p_uni_bar = benchkit::bench("pipeline_overlap_uniform_barriered", warmup, iters, || {
        std::hint::black_box(layer.step(&par_pool, &x));
    });
    let p_uni_ovl = benchkit::bench("pipeline_overlap_uniform_overlapped", warmup, iters, || {
        std::hint::black_box(layer.step_overlapped(&par_pool, &x));
    });
    let p_skw_bar = benchkit::bench("pipeline_overlap_skewed_barriered", warmup, iters, || {
        std::hint::black_box(layer.step_routed_timed(&par_pool, &x, &skew_rt).0);
    });
    let p_skw_ovl = benchkit::bench("pipeline_overlap_skewed_overlapped", warmup, iters, || {
        std::hint::black_box(layer.step_overlapped_routed_timed(&par_pool, &x, &skew_rt).0);
    });

    // --- multi-layer pipeline: barriered vs overlapped executor --------
    // (DESIGN.md §11) — the cross-layer dispatch/FFN overlap window on a
    // 4-layer stack under the interweaved dataflow.
    let ml_cfg = HostMoeConfig {
        n_experts: 8,
        top_k: 2,
        d_model: 64,
        d_ff: 256,
        devices: 4,
    };
    let ml_stack = HostMoeStack::synth(ml_cfg, 4, 0xD1CE);
    let mut ml_x0 = Tensor::zeros(&[128, ml_cfg.d_model]);
    Rng::new(9).fill_normal(ml_x0.data_mut());
    let ml_steps = 6usize;
    let ml_bench = |mode: PipelineMode| {
        let stack = ml_stack.clone();
        let x0 = ml_x0.clone();
        let pool = par_pool;
        move || {
            let mut p = HostPipeline::new_stack(
                stack.clone(),
                Strategy::Interweaved,
                SelectiveSync::None,
                mode,
                &pool,
            );
            std::hint::black_box(p.run(&x0, ml_steps));
        }
    };
    let ml_bar = benchkit::bench(
        "multilayer_overlap_barriered",
        warmup,
        iters,
        ml_bench(PipelineMode::Barriered),
    );
    let ml_ovl = benchkit::bench(
        "multilayer_overlap_overlapped",
        warmup,
        iters,
        ml_bench(PipelineMode::Overlapped),
    );

    // --- placement sweep: solve all three policies + price the plan ----
    let (pe, pd, pk) = (16usize, 8usize, 2usize);
    let p_tokens = 1024usize;
    let probs = skewed_probs(p_tokens, pe, pd, 0xBEEF);
    let p_rt = RoutingTable::from_probs(&probs, pk);
    let p_plan = DispatchPlan::build(&p_rt, p_tokens / pd);
    let mut p_stats = RoutingStats::new(pe, pd);
    p_stats.observe(&p_rt, p_tokens / pd);
    let p_kinds = [
        PlacementKind::Contiguous,
        PlacementKind::LoadBalanced,
        PlacementKind::AffinityAware,
    ];
    let s_place = benchkit::bench("placement_sweep", warmup, iters, || {
        for kind in p_kinds {
            let p = build(kind).place(pe, pd, &p_stats);
            // alternating placements defeat the memo on purpose: this
            // times the solve + the full crossing-bytes scan
            std::hint::black_box(p_plan.cross_bytes(&p, 64, 2));
        }
    });

    // --- topology placement: node-blind vs node-aware on a cluster -----
    // (DESIGN.md §13) — solve the affinity placement flat and on a
    // 4-node hierarchy against the seeded node-skewed workload, split
    // the plan's crossing bytes per fabric, and model the all-to-all
    // step time on the hierarchical cost model. The custom record below
    // carries the byte/time facts into the trajectory.
    let topo = Topology::multinode(4);
    let (te, td, tk) = (32usize, 16usize, 2usize);
    let t_tokens = 1024usize;
    let mut t_stats = RoutingStats::new(te, td);
    for step in 0..3u64 {
        let probs = node_skewed_probs(t_tokens, te, td, topo, 0xD1CE_u64.wrapping_add(step));
        t_stats.observe(&RoutingTable::from_probs(&probs, tk), t_tokens / td);
    }
    let t_probs = node_skewed_probs(t_tokens, te, td, topo, 0xD1CE);
    let t_plan = DispatchPlan::build(&RoutingTable::from_probs(&t_probs, tk), t_tokens / td);
    let s_topo = benchkit::bench("topology_placement_solve", warmup, iters, || {
        let p = build(PlacementKind::AffinityAware).place_on(te, td, topo, &t_stats);
        std::hint::black_box(t_plan.cross_bytes_split(&p, topo, 64, 2));
    });
    let tp_flat = build(PlacementKind::AffinityAware).place(te, td, &t_stats);
    let tp_topo = build(PlacementKind::AffinityAware).place_on(te, td, topo, &t_stats);
    let (fl_intra, fl_inter) = t_plan.cross_bytes_split(&tp_flat, topo, 64, 2);
    let (tp_intra, tp_inter) = t_plan.cross_bytes_split(&tp_topo, topo, 64, 2);
    let tcm = CostModel::new(model_preset("g")?, hardware_profile("rtx4090_pcie")?)
        .with_topology(topo);
    let tt_flat = tcm.t_a2a_split(fl_intra as f64, fl_inter as f64, td);
    let tt_topo = tcm.t_a2a_split(tp_intra as f64, tp_inter as f64, td);
    println!(
        "topology placement (multinode:4, {te} experts / {td} devices): inter-node bytes \
         {fl_inter} flat -> {tp_inter} node-aware, modeled a2a {} -> {}",
        fmt_secs(tt_flat),
        fmt_secs(tt_topo)
    );

    // --- SIMD kernels: scalar oracle vs best detected backend ----------
    // (DESIGN.md §12) — the expert-FFN GEMM at the multi-layer
    // pipeline's shapes (128 tokens, d_model 64 → d_ff 256, fused GELU
    // epilogue), on the serial pool so the kernel itself is what's
    // timed. The bit-exactness contract makes the backend a pure
    // wall-time knob, so the pair gates speed-only.
    let simd_prev = simd::forced_kind();
    let simd_best = simd::detected_kind();
    let mut g_a = Tensor::zeros(&[128, ml_cfg.d_model]);
    Rng::new(21).fill_normal(g_a.data_mut());
    let mut g_bt = Tensor::zeros(&[ml_cfg.d_ff, ml_cfg.d_model]);
    Rng::new(22).fill_normal(g_bt.data_mut());
    simd::set_kind(SimdKind::Scalar);
    let k_scalar = benchkit::bench("simd_kernels_scalar", warmup, iters, || {
        std::hint::black_box(linalg::matmul_bt_gelu_with(&serial_pool, &g_a, &g_bt));
    });
    let k_want = linalg::matmul_bt_gelu_with(&serial_pool, &g_a, &g_bt);
    simd::set_kind(simd_best);
    let k_best = benchkit::bench(
        &format!("simd_kernels_{}", simd_best.name()),
        warmup,
        iters,
        || {
            std::hint::black_box(linalg::matmul_bt_gelu_with(&serial_pool, &g_a, &g_bt));
        },
    );
    let k_got = linalg::matmul_bt_gelu_with(&serial_pool, &g_a, &g_bt);
    match simd_prev {
        Some(k) => simd::set_kind(k),
        None => simd::clear_kind(),
    }

    // --- fleet serving: the burst cell of the §14 acceptance grid ------
    // (DESIGN.md §14) — a 3-replica fleet with a slow replica serving
    // the burst trace behind the least-loaded router, in virtual time.
    // mean_s times the whole discrete-event fleet loop; the custom
    // record below carries the routing (burst p99 per router) and
    // autoscaling (static-vs-autoscaled replica-seconds) facts into the
    // trajectory.
    let s_fleet = benchkit::bench("fleet_serving", warmup, iters, || {
        std::hint::black_box(fleet_exp::burst_cell(RouterKind::LeastLoaded).unwrap());
    });
    let fleet_rr = fleet_exp::burst_cell(RouterKind::RoundRobin)?;
    let fleet_ll = fleet_exp::burst_cell(RouterKind::LeastLoaded)?;
    let fleet_ll2 = fleet_exp::burst_cell(RouterKind::LeastLoaded)?;
    let fleet_static = fleet_exp::diurnal_cell(false)?;
    let fleet_auto = fleet_exp::diurnal_cell(true)?;
    let (fleet_rr_p99, fleet_ll_p99) = (
        fleet_rr.report.latency().p99,
        fleet_ll.report.latency().p99,
    );
    println!(
        "fleet serving (3 replicas, slow-replica burst): p99 {} round-robin -> {} \
         least-loaded; diurnal replica-seconds {:.2} static -> {:.2} autoscaled",
        fmt_secs(fleet_rr_p99),
        fmt_secs(fleet_ll_p99),
        fleet_static.replica_seconds,
        fleet_auto.replica_seconds
    );

    // --- expert replication: the §15 memory-budgeted replication cell --
    // (DESIGN.md §15) — the full 4-mode replication report (three
    // single-owner policies + the replicated mode at equal slot budget)
    // over the seeded skewed workload. The report itself FAILS unless
    // replication strictly wins on max load and step time, so timing it
    // doubles as running the acceptance gate; the custom record below
    // carries the win and the cache hit rate into the trajectory.
    let s_repl = benchkit::bench("expert_replication_report", warmup, iters, || {
        std::hint::black_box(replicate_exp::report(512, 8, 0xD1CE).unwrap());
    });
    let (_, repl_json) = replicate_exp::report(512, 8, 0xD1CE)?;
    let repl_cell = |mode: &str, key: &str| -> f64 {
        repl_json
            .get("rows")
            .and_then(|r| r.as_arr())
            .and_then(|rows| {
                rows.iter()
                    .find(|r| r.get("mode").map(|m| m.as_str()) == Some(Some(mode)))
            })
            .and_then(|r| r.get(key))
            .and_then(|v| v.as_f64())
            .expect("replication report row")
    };
    let single_modes = ["contiguous", "load_balanced", "affinity_aware"];
    let best_single = |key: &str| -> f64 {
        single_modes
            .iter()
            .map(|m| repl_cell(m, key))
            .fold(f64::INFINITY, f64::min)
    };
    let (repl_max, single_max) = (repl_cell("replicated", "max_load"), best_single("max_load"));
    let (repl_step, single_step) = (repl_cell("replicated", "step_s"), best_single("step_s"));
    let (repl_cross, single_cross) = (
        repl_cell("replicated", "cross_bytes_per_step"),
        best_single("cross_bytes_per_step"),
    );
    let repl_hit_rate = repl_json
        .get("cache_replicated")
        .and_then(|c| c.get("hit_rate"))
        .and_then(|v| v.as_f64())
        .expect("replication cache record");
    println!(
        "expert replication (16 experts / 8 devices, equal memory): max load {single_max:.0} \
         single-owner -> {repl_max:.0} replicated, modeled step {} -> {}, cache hit rate {:.2}",
        fmt_secs(single_step),
        fmt_secs(repl_step),
        repl_hit_rate
    );

    let summaries: Vec<Summary> = vec![
        s_serial.clone(),
        s_par.clone(),
        w_serial.clone(),
        w_par.clone(),
        s_place.clone(),
        s_topo.clone(),
        p_uni_bar.clone(),
        p_uni_ovl.clone(),
        p_skw_bar.clone(),
        p_skw_ovl.clone(),
        ml_bar.clone(),
        ml_ovl.clone(),
        k_scalar.clone(),
        k_best.clone(),
        s_fleet.clone(),
        s_repl.clone(),
    ];
    let mut t = Table::new(
        "Perf gate — engine step + sim sweep, serial vs parallel",
        &["case", "mean", "p50", "p95", "p99"],
    );
    for s in &summaries {
        t.row(vec![
            s.name.clone(),
            fmt_secs(s.mean_s),
            fmt_secs(s.p50_s),
            fmt_secs(s.p95_s),
            fmt_secs(s.p99_s),
        ]);
    }
    t.print();
    println!(
        "\nengine-step speedup {:.2}x, sim-sweep speedup {:.2}x, overlapped-vs-barriered \
         {:.2}x uniform / {:.2}x skewed ({} threads, {} cores)",
        s_serial.mean_s / s_par.mean_s,
        w_serial.mean_s / w_par.mean_s,
        p_uni_bar.mean_s / p_uni_ovl.mean_s,
        p_skw_bar.mean_s / p_skw_ovl.mean_s,
        par_threads,
        cores
    );
    let g_flops = 2.0 * 128.0 * ml_cfg.d_ff as f64 * ml_cfg.d_model as f64;
    println!(
        "simd kernels (expert-FFN GEMM 128x{}x{}): scalar {:.2} GFLOP/s, {} {:.2} GFLOP/s \
         — {:.2}x",
        ml_cfg.d_model,
        ml_cfg.d_ff,
        g_flops / k_scalar.mean_s / 1e9,
        simd_best.name(),
        g_flops / k_best.mean_s / 1e9,
        k_scalar.mean_s / k_best.mean_s
    );

    // --- trajectory ----------------------------------------------------
    let bench_path = repo_root().join("BENCH_engine.json");
    benchkit::append_jsonl(&bench_path, &summaries)?;
    // the topology record carries the flat-vs-multinode inter-node byte
    // split and modeled a2a step times alongside the solve timing
    // (mean_s), so the trajectory tracks the §13 placement win per PR
    {
        use std::io::Write;
        let mut f = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&bench_path)?;
        writeln!(
            f,
            "{{\"name\":\"topology_placement\",\"mean_s\":{:.9},\
             \"inter_bytes_flat\":{fl_inter},\"inter_bytes_topo\":{tp_inter},\
             \"intra_bytes_flat\":{fl_intra},\"intra_bytes_topo\":{tp_intra},\
             \"a2a_s_flat\":{tt_flat:.9},\"a2a_s_topo\":{tt_topo:.9}}}",
            tt_topo
        )?;
        // the fleet record carries the §14 routing and autoscaling facts
        // (burst p99 per router, static-vs-autoscaled replica-seconds)
        // alongside the fleet-loop timing (mean_s)
        writeln!(
            f,
            "{{\"name\":\"fleet_serving\",\"mean_s\":{:.9},\
             \"burst_p99_rr\":{fleet_rr_p99:.9},\"burst_p99_ll\":{fleet_ll_p99:.9},\
             \"replica_s_static\":{:.9},\"replica_s_auto\":{:.9},\
             \"slo_attainment_auto\":{:.9}}}",
            s_fleet.mean_s,
            fleet_static.replica_seconds,
            fleet_auto.replica_seconds,
            fleet_auto.slo_attainment()
        )?;
        // the replication record carries the §15 equal-memory win
        // (max load, crossing bytes, modeled step time) and the
        // expert-cache hit rate alongside the report timing (mean_s)
        writeln!(
            f,
            "{{\"name\":\"expert_replication\",\"mean_s\":{:.9},\
             \"max_load_single\":{single_max:.3},\"max_load_replicated\":{repl_max:.3},\
             \"cross_bytes_single\":{single_cross:.1},\"cross_bytes_replicated\":{repl_cross:.1},\
             \"step_s_single\":{single_step:.9},\"step_s_replicated\":{repl_step:.9},\
             \"cache_hit_rate\":{repl_hit_rate:.6}}}",
            s_repl.mean_s
        )?;
    }
    println!(
        "appended {} records to {}",
        summaries.len() + 3,
        bench_path.display()
    );

    // --- gates ---------------------------------------------------------
    // determinism: parallel output bit-exact vs serial, always checked
    let want = layer.step(&serial_pool, &x);
    for tn in [2usize, 4] {
        let got = layer.step(&ParPool::new(tn), &x);
        assert!(want == got, "engine step must be bit-exact at {tn} threads");
    }
    // the overlapped executor shares those bits exactly (DESIGN.md §10)
    for tn in [1usize, 2, 4] {
        let got = layer.step_overlapped(&ParPool::new(tn), &x);
        assert!(want == got, "overlapped step must be bit-exact at {tn} threads");
    }
    {
        let (want_s, _) = layer.step_routed_timed(&serial_pool, &x, &skew_rt);
        let (got_s, _) = layer.step_overlapped_routed_timed(&par_pool, &x, &skew_rt);
        assert!(want_s == got_s, "overlapped skewed step must be bit-exact");
    }
    // multi-layer pipeline (DESIGN.md §11): overlapped executor bit-exact
    // vs barriered across widths, always checked
    {
        let want_ml = {
            let mut p = HostPipeline::new_stack(
                ml_stack.clone(),
                Strategy::Interweaved,
                SelectiveSync::None,
                PipelineMode::Barriered,
                &serial_pool,
            );
            p.run(&ml_x0, ml_steps).out
        };
        for tn in [1usize, 2, 4] {
            let mut p = HostPipeline::new_stack(
                ml_stack.clone(),
                Strategy::Interweaved,
                SelectiveSync::None,
                PipelineMode::Overlapped,
                &ParPool::new(tn),
            );
            let got = p.run(&ml_x0, ml_steps).out;
            assert!(
                want_ml == got,
                "multilayer overlapped pipeline must be bit-exact at {tn} threads"
            );
        }
    }
    // SIMD (DESIGN.md §12): the detected backend's bits must equal the
    // scalar oracle's on the gated GEMM, always checked
    assert!(
        k_want == k_got,
        "simd backend {} diverged from the scalar oracle on the perf-gate GEMM",
        simd_best.name()
    );
    // fleet (DESIGN.md §14): repeated runs of the same fleet cell must
    // be bit-exact — assignment trace, percentiles and the
    // replica-seconds bill — always checked
    assert!(
        fleet_ll.report.batches == fleet_ll2.report.batches,
        "fleet serving trace must be deterministic across runs"
    );
    assert!(
        fleet_ll.report.latency().p99.to_bits() == fleet_ll2.report.latency().p99.to_bits()
            && fleet_ll.replica_seconds.to_bits() == fleet_ll2.replica_seconds.to_bits(),
        "fleet percentiles / replica-seconds must be bit-exact across runs"
    );
    // placement: the affinity policy must not add crossing bytes on the
    // skewed workload (DESIGN.md §9), always checked
    let p_contig = build(PlacementKind::Contiguous).place(pe, pd, &p_stats);
    let p_aff = build(PlacementKind::AffinityAware).place(pe, pd, &p_stats);
    assert!(
        p_plan.cross_bytes(&p_aff, 64, 2) <= p_plan.cross_bytes(&p_contig, 64, 2),
        "affinity placement regressed crossing bytes"
    );
    // JSON-lines validity of the trajectory file
    let text = std::fs::read_to_string(&bench_path)?;
    let mut lines = 0usize;
    for line in text.lines() {
        Json::parse(line)
            .map_err(|e| anyhow::anyhow!("BENCH_engine.json line {}: {e}", lines + 1))?;
        lines += 1;
    }
    assert!(lines > summaries.len(), "trajectory must retain records");
    if check {
        // topology gate (DESIGN.md §13): the node-aware affinity solve
        // must not ship more bytes over the NIC than the node-blind one
        // on the seeded node-skewed workload — deterministic, but
        // gated here with the other --check assertions
        assert!(
            tp_inter <= fl_inter,
            "node-aware placement regressed inter-node bytes: {tp_inter} vs flat {fl_inter}"
        );
        assert!(
            tt_topo <= tt_flat,
            "node-aware placement regressed modeled a2a time: {tt_topo} vs flat {tt_flat}"
        );
        if cores >= 2 {
            // median with a small noise margin: a real speedup has huge
            // headroom under this, while a broken pool (par == serial)
            // still fails on any honest multi-core host
            assert!(
                s_par.p50_s <= 1.05 * s_serial.p50_s,
                "parallel engine step regressed: p50 {} vs serial p50 {}",
                s_par.p50_s,
                s_serial.p50_s
            );
            // pipeline overlap gate (DESIGN.md §10): on the skewed
            // routing workload — the exact case dynamic scheduling
            // exists for — the overlapped executor must not be slower
            // than the barriered baseline at >= 2 threads (same small
            // noise margin as the serial-vs-parallel gate).
            assert!(
                p_skw_ovl.p50_s <= 1.05 * p_skw_bar.p50_s,
                "overlapped executor regressed on skewed routing: p50 {} vs barriered p50 {}",
                p_skw_ovl.p50_s,
                p_skw_bar.p50_s
            );
        } else {
            println!("single-core host: skipping parallel-vs-serial and pipeline-overlap gates");
        }
        // SIMD gate (DESIGN.md §12): the detected backend must not lose
        // to the scalar oracle on the expert-FFN GEMM. Single-threaded
        // timing, so unlike the pool gates this runs on any core count.
        assert!(
            k_best.p50_s <= 1.05 * k_scalar.p50_s,
            "simd backend {} regressed vs the scalar oracle: p50 {} vs scalar p50 {}",
            simd_best.name(),
            k_best.p50_s,
            k_scalar.p50_s
        );
        // fleet gates (DESIGN.md §14): deterministic virtual-time facts,
        // but gated here with the other --check assertions. Least-loaded
        // routing must beat round-robin on tail latency when one replica
        // is slow, and the autoscaled diurnal fleet must bill fewer
        // replica-seconds than the static max-size fleet.
        assert!(
            fleet_ll_p99 <= fleet_rr_p99,
            "least-loaded router regressed burst p99: {fleet_ll_p99} vs round-robin {fleet_rr_p99}"
        );
        assert!(
            fleet_auto.replica_seconds < fleet_static.replica_seconds,
            "autoscaled fleet billed {} replica-seconds vs static {}",
            fleet_auto.replica_seconds,
            fleet_static.replica_seconds
        );
        // replication gates (DESIGN.md §15): deterministic modeled
        // facts at equal total parameter memory — the replicated mode
        // must not lose to the best single-owner policy on any tracked
        // axis (the report already enforces STRICT wins on max load
        // and step time; these re-assert the trajectory values).
        assert!(
            repl_max <= single_max,
            "replication regressed max device load: {repl_max} vs single-owner {single_max}"
        );
        assert!(
            repl_step <= single_step,
            "replication regressed modeled step time: {repl_step} vs single-owner {single_step}"
        );
        assert!(
            repl_cross <= single_cross,
            "replication regressed crossing bytes: {repl_cross} vs single-owner {single_cross}"
        );
        println!("perf gate OK ({lines} trajectory records)");
    }
    Ok(())
}
