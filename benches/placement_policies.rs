//! Placement-policy study (DESIGN.md §9): load imbalance, crossing
//! bytes and step time of contiguous / load-balanced / affinity-aware
//! expert placement on a seeded skewed workload, rebalance migrations
//! priced in. Artifact-free; also reachable as `dice exp placement`.
use dice::exp::{placement::report, write_results};

fn main() -> anyhow::Result<()> {
    let (t, j) = report(2048, 16, 4, 1234)?;
    t.print();
    write_results("placement_policies", &t.render(), &j)?;
    Ok(())
}
