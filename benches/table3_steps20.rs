//! Reproduces Table 3: 20-step quality + simulated XL-scale speedup
//! (4 synchronized warmup steps, as in the paper).
use dice::cli::Args;
use dice::exp::{quality::quality_table, write_results, Ctx};

fn main() -> anyhow::Result<()> {
    let a = Args::parse();
    let ctx = Ctx::open()?;
    let samples = a.usize_or("samples", 256);
    let (t, j) = quality_table(
        &ctx,
        &format!("Table 3 — quality + speedup at 20 steps ({samples} samples, 4 warmup)"),
        samples, 20, 4, true, a.u64_or("seed", 1234),
    )?;
    t.print();
    write_results("table3_steps20", &t.render(), &j)?;
    Ok(())
}
