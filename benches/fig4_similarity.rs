//! Reproduces Figure 4: step-wise routing-similarity heatmaps (the
//! redundancy that asynchronous EP relies on).
use dice::cli::Args;
use dice::exp::{similarity::fig4, write_results, Ctx};

fn main() -> anyhow::Result<()> {
    let a = Args::parse();
    let ctx = Ctx::open()?;
    let (t, j) = fig4(&ctx, a.usize_or("steps", 20), a.u64_or("seed", 7))?;
    t.print();
    write_results("fig4_similarity", &t.render(), &j)?;
    Ok(())
}
