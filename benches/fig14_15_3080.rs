//! Reproduces Figures 14-15: the same scaling experiments on the
//! 8x RTX 3080 profile (the paper's secondary testbed).
use dice::cli::Args;
use dice::config::{obj, Json};
use dice::exp::{scaling::scaling, write_results};

fn main() -> anyhow::Result<()> {
    let a = Args::parse();
    let steps = a.usize_or("steps", 50);
    let mut md = String::new();
    let mut payload = Vec::new();
    for model in ["xl", "g"] {
        let (tables, j) = scaling(model, "rtx3080_pcie", steps)?;
        for t in tables {
            t.print();
            md.push_str(&t.render());
        }
        payload.push(obj(vec![("model", Json::Str(model.into())), ("data", j)]));
    }
    write_results("fig14_15_3080", &md, &Json::Arr(payload))?;
    Ok(())
}
