//! Reproduces Table 2: 10-step quality + simulated XL-scale speedup
//! (2 synchronized warmup steps, as in the paper).
use dice::cli::Args;
use dice::exp::{quality::quality_table, write_results, Ctx};

fn main() -> anyhow::Result<()> {
    let a = Args::parse();
    let ctx = Ctx::open()?;
    let samples = a.usize_or("samples", 256);
    let (t, j) = quality_table(
        &ctx,
        &format!("Table 2 — quality + speedup at 10 steps ({samples} samples, 2 warmup)"),
        samples, 10, 2, true, a.u64_or("seed", 1234),
    )?;
    t.print();
    write_results("table2_steps10", &t.render(), &j)?;
    Ok(())
}
