//! Reproduces Figure 10: the latency-quality trade-off scatter
//! (DistriFusion OOM at the plotting point, as in the paper).
use dice::cli::Args;
use dice::exp::{tradeoff::fig10, write_results, Ctx};

fn main() -> anyhow::Result<()> {
    let a = Args::parse();
    let ctx = Ctx::open()?;
    let samples = a.usize_or("samples", 128);
    let steps = a.usize_or("steps", 50);
    let (t, j) = fig10(&ctx, samples, steps, a.usize_or("warmup", 4), a.u64_or("seed", 1234))?;
    t.print();
    write_results("fig10_tradeoff", &t.render(), &j)?;
    Ok(())
}
