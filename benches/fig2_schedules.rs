//! Reproduces Figure 2: execution-flow comparison (staleness, step
//! latency, buffers) of sync / displaced / interweaved EP.
use dice::cli::Args;
use dice::exp::{schedules::fig2, write_results, Ctx};

fn main() -> anyhow::Result<()> {
    let a = Args::parse();
    let ctx = Ctx::open()?;
    let (t, j) = fig2(&ctx, a.usize_or("steps", 8))?;
    t.print();
    write_results("fig2_schedules", &t.render(), &j)?;
    Ok(())
}
