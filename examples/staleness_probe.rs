//! Staleness sensitivity probe — reproduces the Sec. 4.2 insight that
//! DEEP MoE layers are the staleness-vulnerable ones: inject staleness
//! into one layer at a time (that layer async, all others synchronous)
//! and measure the output deviation each injection causes.
//!
//!     cargo run --release --example staleness_probe

use dice::cli::Args;
use dice::config::{DiceOptions, Strategy};
use dice::coordinator::{Engine, EngineConfig};
use dice::exp::Ctx;

fn main() -> anyhow::Result<()> {
    let a = Args::parse();
    let steps = a.usize_or("steps", 20);
    let ctx = Ctx::open()?;
    let labels: Vec<usize> = (0..32).map(|i| i % 4).collect();

    // synchronous reference
    let sync = Engine::new(
        &ctx.rt,
        &ctx.bank,
        EngineConfig {
            strategy: Strategy::SyncEp,
            opts: DiceOptions::none(),
            devices: 4,
        },
    )?;
    let (ref_x, _) = sync.generate(&labels, steps, 77, None)?;

    println!("per-layer staleness injection (displaced dataflow on ONE layer, {steps} steps):\n");
    println!("{:<8} {:>14} {:>16}", "layer", "drift (rel l2)", "ΔFID-pixel vs sync");
    let n_layers = ctx.rt.model.n_layers;
    let mut drifts = Vec::new();
    for layer in 0..n_layers {
        // async only on `layer`: every other layer runs synchronously.
        let eng = Engine::new(
            &ctx.rt,
            &ctx.bank,
            EngineConfig {
                strategy: Strategy::DisplacedEp,
                opts: DiceOptions::none()
                    .with_warmup(2)
                    .with_only_async_layer(layer),
                devices: 4,
            },
        )?;
        let (x, _) = eng.generate(&labels, steps, 77, None)?;
        let drift = x.rel_l2(&ref_x)?;
        let dfid = dice::exp::quality::delta_fid(&x, &ref_x);
        println!("{layer:<8} {drift:>14.5} {dfid:>16.5}");
        drifts.push(drift);
    }
    let shallow: f32 = drifts[..n_layers / 2].iter().sum();
    let deep: f32 = drifts[n_layers / 2..].iter().sum();
    println!(
        "\nshallow-half drift sum {shallow:.4}  vs  deep-half drift sum {deep:.4}  ({})",
        if deep > shallow {
            "deep layers are more vulnerable — synchronize deep (DICE's choice)"
        } else {
            "shallow layers dominate at this scale"
        }
    );
    Ok(())
}
