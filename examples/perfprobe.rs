//! Perf probe for the §Perf log: one DICE quality run, timed.
use std::time::Instant;
fn main() -> anyhow::Result<()> {
    let rt = dice::runtime::Runtime::open(std::path::Path::new("artifacts"))?;
    let w = rt.load_weights()?;
    let bank = dice::runtime::WeightBank::stage(&rt, &w)?;
    let eng = dice::coordinator::Engine::new(&rt, &bank, dice::coordinator::EngineConfig {
        strategy: dice::config::Strategy::Interweaved,
        opts: dice::config::DiceOptions::dice().with_warmup(4),
        devices: 4,
    })?;
    let labels: Vec<usize> = (0..32).map(|i| i % 4).collect();
    // warm compile cache
    let _ = eng.generate(&labels, 2, 1, None)?;
    let t0 = Instant::now();
    let (x, stats) = eng.generate(&labels, 50, 1, None)?;
    let dt = t0.elapsed().as_secs_f64();
    println!("32 samples, 50 steps: {:.2}s  ({} execs, {:.0} execs/s)  checksum {:.4}",
        dt, stats.exec_calls, stats.exec_calls as f64 / dt, x.data().iter().map(|v| v.abs() as f64).sum::<f64>() / x.len() as f64);
    Ok(())
}
