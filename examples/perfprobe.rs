//! Perf probe for the §Perf log.
//!
//! Default mode runs one DICE quality run over the AOT artifacts and
//! times it. `--sim` needs NO artifacts: it drives the host MoE hot
//! path through `dice::coordinator::HostPipeline` for `--steps` steps
//! and reports per-phase BUSY time — route / dispatch / expert /
//! combine — alongside the run's wall time and their ratio (the
//! measured overlap), plus the cost model's price for the measured
//! dispatch plan.
//!
//! Knobs (DESIGN.md §10): `--pipeline {barriered,overlapped}` selects
//! the step executor, `--strategy {sync,interweaved,displaced}` the
//! staleness dataflow (the staleness ledger's measured ages are
//! printed), `--threads N` pins the worker-pool width in both modes.
//! With the barriered executor phases are sequential, so busy ≈ wall;
//! with overlap `wall ≤ busy` and the gap is the win.
//!
//!     cargo run --release --example perfprobe -- --sim --threads 4 \
//!         --pipeline overlapped --strategy interweaved

use std::time::Instant;

use dice::benchkit::{fmt_bytes, fmt_secs, Table};
use dice::cli::Args;
use dice::config::{PipelineMode, Strategy};
use dice::coordinator::HostPipeline;
use dice::moe::host::{HostMoeConfig, HostMoeLayer};
use dice::netsim::CostModel;
use dice::par::ParPool;
use dice::rng::Rng;
use dice::tensor::Tensor;

fn main() -> anyhow::Result<()> {
    let a = Args::parse();
    if let Some(t) = a.get("threads") {
        dice::par::set_threads(t.parse()?);
    }
    if a.flag("sim") {
        return sim_probe(&a);
    }
    let rt = dice::runtime::Runtime::open(std::path::Path::new("artifacts"))?;
    let w = rt.load_weights()?;
    let bank = dice::runtime::WeightBank::stage(&rt, &w)?;
    let eng = dice::coordinator::Engine::new(&rt, &bank, dice::coordinator::EngineConfig {
        strategy: dice::config::Strategy::Interweaved,
        opts: dice::config::DiceOptions::dice().with_warmup(4),
        devices: 4,
    })?;
    let labels: Vec<usize> = (0..32).map(|i| i % 4).collect();
    // warm compile cache
    let _ = eng.generate(&labels, 2, 1, None)?;
    let t0 = Instant::now();
    let (x, stats) = eng.generate(&labels, 50, 1, None)?;
    let dt = t0.elapsed().as_secs_f64();
    println!("32 samples, 50 steps: {:.2}s  ({} execs, {:.0} execs/s)  checksum {:.4}",
        dt, stats.exec_calls, stats.exec_calls as f64 / dt, x.data().iter().map(|v| v.abs() as f64).sum::<f64>() / x.len() as f64);
    Ok(())
}

/// Artifact-free probe: host pipeline steps with per-phase busy + wall
/// timings and measured staleness.
fn sim_probe(a: &Args) -> anyhow::Result<()> {
    let pool = ParPool::current();
    let steps = a.usize_or("steps", 50);
    let n_tokens = a.usize_or("tokens", 512);
    let mode = PipelineMode::parse(&a.str_or("pipeline", "barriered"))?;
    let strategy = Strategy::parse(&a.str_or("strategy", "sync"))?;
    if !matches!(
        strategy,
        Strategy::SyncEp | Strategy::DisplacedEp | Strategy::Interweaved
    ) {
        anyhow::bail!(
            "--strategy {} has no host-pipeline dataflow (use sync|interweaved|displaced)",
            strategy.name()
        );
    }
    let cfg = HostMoeConfig {
        n_experts: a.usize_or("experts", 8),
        top_k: 2,
        d_model: a.usize_or("dim", 128),
        d_ff: 4 * a.usize_or("dim", 128),
        devices: a.usize_or("devices", 4),
    };
    let layer = HostMoeLayer::synth(cfg, 0xD1CE);
    let mut x = Tensor::zeros(&[n_tokens, cfg.d_model]);
    Rng::new(1).fill_normal(x.data_mut());

    let mut pipe = HostPipeline::new(layer, strategy, mode, &pool);
    let t0 = Instant::now();
    let rep = pipe.run(&x, steps);
    let wall = t0.elapsed().as_secs_f64();
    let checksum =
        rep.out.data().iter().map(|v| v.abs() as f64).sum::<f64>() / rep.out.len() as f64;

    let phases = rep.phases;
    let mut t = Table::new(
        &format!(
            "perfprobe --sim — {} / {} — {} steps, {} tokens, {} experts on {} devices, {} threads",
            strategy.name(),
            mode.name(),
            steps,
            n_tokens,
            cfg.n_experts,
            cfg.devices,
            pool.threads()
        ),
        &["phase", "busy total", "busy/step", "share"],
    );
    let total = phases.total_s().max(1e-12);
    for (name, s) in [
        ("route", phases.route_s),
        ("dispatch", phases.dispatch_s),
        ("expert", phases.expert_s),
        ("combine", phases.combine_s),
    ] {
        t.row(vec![
            name.into(),
            fmt_secs(s),
            fmt_secs(s / steps as f64),
            format!("{:.1}%", 100.0 * s / total),
        ]);
    }
    t.print();

    // the HostPhases invariant (DESIGN.md §10): busy no longer sums to
    // wall once phases overlap — report both and the ratio.
    println!(
        "\nwall {:.2}s ({:.1} steps/s) vs busy {:.2}s — overlap {:.2}x; \
         staleness mean {:.2} / max {} (settled contract: {}); peak buffers {}",
        wall,
        steps as f64 / wall,
        phases.total_s(),
        phases.total_s() / phases.wall_s.max(1e-12),
        rep.staleness.mean_age(strategy.step_staleness()),
        rep.staleness.max_age(0),
        strategy.step_staleness(),
        fmt_bytes(rep.peak_buffer_bytes),
    );
    println!(
        "arena: {} hits / {} misses, {} slots parked; checksum {:.4}",
        pipe.arena().hits,
        pipe.arena().misses,
        pipe.arena().free_slots(),
        checksum
    );

    // price the measured dispatch plan at paper scale (memoized
    // cross-bytes: both collectives priced from one entry scan)
    let cm = CostModel::new(
        dice::config::model_preset("xl")?,
        dice::config::hardware_profile("rtx4090_pcie")?,
    );
    let (_, plan) = pipe.layer().route(&pool, &rep.out);
    let t_a2a = cm.t_a2a_measured(&plan, pipe.layer().placement());
    println!(
        "modelled a2a per collective from the measured plan: {}",
        fmt_secs(t_a2a)
    );
    Ok(())
}
