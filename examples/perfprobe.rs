//! Perf probe for the §Perf log.
//!
//! Default mode runs one DICE quality run over the AOT artifacts and
//! times it. `--sim` needs NO artifacts: it drives the host engine step
//! (`dice::moe::host`, the same dispatch→expert→combine hot path) for
//! `--steps` steps and reports per-phase wall time — route / dispatch /
//! expert / combine — plus the cost model's price for the measured
//! dispatch plan. `--threads N` pins the worker-pool width in both
//! modes.
//!
//!     cargo run --release --example perfprobe -- --sim --threads 4

use std::time::Instant;

use dice::benchkit::{fmt_secs, Table};
use dice::cli::Args;
use dice::moe::host::{HostMoeConfig, HostMoeLayer, HostPhases};
use dice::netsim::CostModel;
use dice::par::ParPool;
use dice::rng::Rng;
use dice::tensor::Tensor;

fn main() -> anyhow::Result<()> {
    let a = Args::parse();
    if let Some(t) = a.get("threads") {
        dice::par::set_threads(t.parse()?);
    }
    if a.flag("sim") {
        return sim_probe(&a);
    }
    let rt = dice::runtime::Runtime::open(std::path::Path::new("artifacts"))?;
    let w = rt.load_weights()?;
    let bank = dice::runtime::WeightBank::stage(&rt, &w)?;
    let eng = dice::coordinator::Engine::new(&rt, &bank, dice::coordinator::EngineConfig {
        strategy: dice::config::Strategy::Interweaved,
        opts: dice::config::DiceOptions::dice().with_warmup(4),
        devices: 4,
    })?;
    let labels: Vec<usize> = (0..32).map(|i| i % 4).collect();
    // warm compile cache
    let _ = eng.generate(&labels, 2, 1, None)?;
    let t0 = Instant::now();
    let (x, stats) = eng.generate(&labels, 50, 1, None)?;
    let dt = t0.elapsed().as_secs_f64();
    println!("32 samples, 50 steps: {:.2}s  ({} execs, {:.0} execs/s)  checksum {:.4}",
        dt, stats.exec_calls, stats.exec_calls as f64 / dt, x.data().iter().map(|v| v.abs() as f64).sum::<f64>() / x.len() as f64);
    Ok(())
}

/// Artifact-free probe: host engine steps with per-phase timings.
fn sim_probe(a: &Args) -> anyhow::Result<()> {
    let pool = ParPool::current();
    let steps = a.usize_or("steps", 50);
    let n_tokens = a.usize_or("tokens", 512);
    let cfg = HostMoeConfig {
        n_experts: a.usize_or("experts", 8),
        top_k: 2,
        d_model: a.usize_or("dim", 128),
        d_ff: 4 * a.usize_or("dim", 128),
        devices: a.usize_or("devices", 4),
    };
    let layer = HostMoeLayer::synth(cfg, 0xD1CE);
    let mut x = Tensor::zeros(&[n_tokens, cfg.d_model]);
    Rng::new(1).fill_normal(x.data_mut());

    let t0 = Instant::now();
    let mut phases = HostPhases::default();
    let mut checksum = 0.0f64;
    for _ in 0..steps {
        let (out, ph) = layer.step_timed(&pool, &x);
        phases.accumulate(&ph);
        checksum = out.data().iter().map(|v| v.abs() as f64).sum::<f64>() / out.len() as f64;
        // feed a damped output back in so every step routes fresh data
        for (xi, oi) in x.data_mut().iter_mut().zip(out.data()) {
            *xi = 0.7 * *xi + 0.3 * oi;
        }
    }
    let wall = t0.elapsed().as_secs_f64();

    let mut t = Table::new(
        &format!(
            "perfprobe --sim — {} steps, {} tokens, {} experts on {} devices, {} threads",
            steps,
            n_tokens,
            cfg.n_experts,
            cfg.devices,
            pool.threads()
        ),
        &["phase", "total", "per step", "share"],
    );
    let total = phases.total_s().max(1e-12);
    for (name, s) in [
        ("route", phases.route_s),
        ("dispatch", phases.dispatch_s),
        ("expert", phases.expert_s),
        ("combine", phases.combine_s),
    ] {
        t.row(vec![
            name.into(),
            fmt_secs(s),
            fmt_secs(s / steps as f64),
            format!("{:.1}%", 100.0 * s / total),
        ]);
    }
    t.print();

    // price the measured dispatch plan at paper scale (memoized
    // cross-bytes: both collectives priced from one entry scan)
    let cm = CostModel::new(
        dice::config::model_preset("xl")?,
        dice::config::hardware_profile("rtx4090_pcie")?,
    );
    let (_, plan) = layer.route(&pool, &x);
    let t_a2a = cm.t_a2a_measured(&plan, layer.placement());
    println!(
        "\nwall {:.2}s ({:.1} steps/s), checksum {:.4}; modelled a2a per collective \
         from the measured plan: {}",
        wall,
        steps as f64 / wall,
        checksum,
        fmt_secs(t_a2a)
    );
    Ok(())
}
