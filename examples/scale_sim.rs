//! Paper-scale what-if explorer: simulate any (model, hardware, batch,
//! devices, strategy) point and print latency / a2a share / memory.
//! `--topology multinode:4` (or `rail`, `fattree:<o>`) prices a
//! hierarchical cluster — hundreds of devices across dozens of nodes —
//! with inter-node bytes charged at the NIC (DESIGN.md §13).
//!
//!     cargo run --release --example scale_sim -- --model g --hw nvlink --batch 8
//!     cargo run --release --example scale_sim -- --devices 256 --topology multinode:32

use dice::cli::Args;
use dice::config::{hardware_profile, model_preset, DiceOptions, Strategy};
use dice::coordinator::{simulate_sweep, SweepCase};
use dice::benchkit::{fmt_bytes, fmt_secs, Table};
use dice::netsim::{CostModel, Topology, Workload};

fn main() -> anyhow::Result<()> {
    let a = Args::parse();
    if let Some(t) = a.get("threads") {
        dice::par::set_threads(t.parse()?);
    }
    let model = model_preset(&a.str_or("model", "xl"))?;
    let hw = hardware_profile(&a.str_or("hw", "rtx4090_pcie"))?;
    let batch = a.usize_or("batch", 16);
    let devices = a.usize_or("devices", 8);
    let steps = a.usize_or("steps", 50);
    let topo = Topology::parse(&a.str_or("topology", "flat"))?;
    let cm = CostModel::new(model.clone(), hw.clone()).with_topology(topo);
    let wl = Workload {
        local_batch: batch,
        devices,
        tokens: model.tokens(),
    };
    let mut t = Table::new(
        &format!(
            "{} on {}x {} ({} topology, {} nodes) — local batch {batch}, {steps} steps",
            model.name,
            devices,
            hw.name,
            topo.name(),
            topo.nodes_for(devices)
        ),
        &["Strategy", "Total", "Step", "a2a share", "Memory", "OOM"],
    );
    let configs = [
        ("sync EP", Strategy::SyncEp, DiceOptions::none()),
        ("displaced EP", Strategy::DisplacedEp, DiceOptions::none()),
        ("interweaved", Strategy::Interweaved, DiceOptions::none()),
        ("DICE", Strategy::Interweaved, DiceOptions::dice()),
        ("DistriFusion", Strategy::DistriFusion, DiceOptions::none()),
        ("staggered batch", Strategy::StaggeredBatch, DiceOptions::none()),
    ];
    // all strategies simulate concurrently on the worker pool
    let cases: Vec<SweepCase> = configs
        .iter()
        .map(|&(_, strategy, opts)| SweepCase {
            wl,
            strategy,
            opts,
            steps,
        })
        .collect();
    let reports = simulate_sweep(&cm, &cases);
    for ((name, _, _), r) in configs.iter().zip(reports) {
        t.row(vec![
            (*name).into(),
            fmt_secs(r.total_time),
            fmt_secs(r.step_time),
            format!("{:.1}%", r.a2a_share * 100.0),
            fmt_bytes(r.mem.total as usize),
            if r.mem.oom { "OOM".into() } else { "-".into() },
        ]);
    }
    t.print();
    Ok(())
}
