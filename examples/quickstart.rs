//! Quickstart: load the AOT artifacts, generate one batch of images
//! with DICE (interweaved parallelism + selective sync + conditional
//! communication) on 4 logical devices, and report quality + the
//! modelled latency at the paper's scale.
//!
//!     make artifacts && cargo run --release --example quickstart

use dice::config::{hardware_profile, model_preset, DiceOptions, Strategy};
use dice::coordinator::{simulate, Engine, EngineConfig};
use dice::exp::Ctx;
use dice::netsim::{CostModel, Workload};

fn main() -> anyhow::Result<()> {
    let ctx = Ctx::open()?;
    println!(
        "model: tiny DiT-MoE ({} layers, {} experts top-{}, d={})",
        ctx.rt.model.n_layers, ctx.rt.model.n_experts, ctx.rt.model.top_k, ctx.rt.model.d_model
    );

    let eng = Engine::new(
        &ctx.rt,
        &ctx.bank,
        EngineConfig {
            strategy: Strategy::Interweaved,
            opts: DiceOptions::dice().with_warmup(4),
            devices: 4,
        },
    )?;
    let labels: Vec<usize> = (0..32).map(|i| i % 4).collect();
    let t0 = std::time::Instant::now();
    let (samples, stats) = eng.generate(&labels, 50, 0xD1CE, None)?;
    let wall = t0.elapsed().as_secs_f64();

    let q = dice::quality::evaluate(&ctx.rt, &ctx.bank, &samples, &ctx.refs)?;
    println!(
        "generated {} samples in {wall:.2}s host wall-clock ({} PJRT execs)",
        samples.shape()[0],
        stats.exec_calls
    );
    println!(
        "quality: FID-proxy {:.2}  sFID-proxy {:.2}  IS {:.2}  precision {:.2}  recall {:.2}",
        q.fid, q.sfid, q.is_score, q.precision, q.recall
    );
    println!(
        "staleness: mean {:.2} steps (max {})",
        stats.staleness.mean_age(4),
        stats.staleness.max_age(4)
    );
    println!(
        "comm: {} fresh bytes, {} saved by conditional communication",
        stats.fresh_bytes, stats.saved_bytes
    );

    // modelled latency of the same schedule at the paper's scale
    let cm = CostModel::new(model_preset("xl")?, hardware_profile("rtx4090_pcie")?);
    let wl = Workload {
        local_batch: 16,
        devices: 8,
        tokens: cm.model.tokens(),
    };
    let dice_t = simulate(&cm, &wl, Strategy::Interweaved, &DiceOptions::dice(), 50);
    let sync_t = simulate(&cm, &wl, Strategy::SyncEp, &DiceOptions::none(), 50);
    println!(
        "modelled XL/8x4090 latency: DICE {:.2}s vs sync EP {:.2}s  ({:.2}x speedup)",
        dice_t.total_time,
        sync_t.total_time,
        sync_t.total_time / dice_t.total_time
    );
    Ok(())
}
