//! END-TO-END DRIVER (EXPERIMENTS.md §E2E): serve a Poisson request
//! trace through the full stack — workload generator → dynamic batcher
//! (shape buckets) → DICE expert-parallel engine on 4 logical devices
//! with REAL numerics over the AOT artifacts → per-request latency /
//! throughput (virtual time at the modelled 8×4090 scale) → quality of
//! the actually-served samples.
//!
//!     cargo run --release --example serve_trace -- --requests 96 --rate 2.0

use dice::cli::Args;
use dice::config::{hardware_profile, model_preset, DiceOptions, Strategy};
use dice::coordinator::{Engine, EngineConfig};
use dice::exp::Ctx;
use dice::netsim::CostModel;
use dice::server::{serve, BatchPolicy};
use dice::workload::poisson_trace;

fn main() -> anyhow::Result<()> {
    let a = Args::parse();
    let n_requests = a.usize_or("requests", 96);
    let rate = a.f64_or("rate", 2.0);
    let steps = a.usize_or("steps", 50);

    let ctx = Ctx::open()?;
    let strategy = Strategy::parse(&a.str_or("strategy", "interweaved"))?;
    let eng = Engine::new(
        &ctx.rt,
        &ctx.bank,
        EngineConfig {
            strategy,
            opts: DiceOptions::dice().with_warmup(4),
            devices: 4,
        },
    )?;
    let cm = CostModel::new(model_preset("xl")?, hardware_profile("rtx4090_pcie")?);

    let trace = poisson_trace(n_requests, rate, ctx.rt.model.n_classes, 42);
    let policy = BatchPolicy {
        max_global: 32,
        max_wait: 3.0,
    };
    println!(
        "serving {n_requests} requests (poisson {rate}/s) with {} on 4 logical devices, {steps} steps...",
        strategy.name()
    );
    let t0 = std::time::Instant::now();
    let rep = serve(&eng, &cm, &trace, policy, steps, 7)?;
    let wall = t0.elapsed().as_secs_f64();

    println!("\n== serve report (virtual time @ XL scale, real numerics @ tiny) ==");
    println!("host wall-clock          {wall:.1}s");
    println!("virtual makespan         {:.1}s", rep.span);
    println!("throughput               {:.2} req/s", rep.throughput);
    let h = rep.metrics.hist("request.latency").unwrap();
    println!(
        "request latency          mean {:.1}s  p50 {:.1}s  p99 {:.1}s",
        h.mean(),
        h.percentile(50.0),
        h.percentile(99.0)
    );
    println!("batches served           {}", rep.batches.len());
    println!(
        "padded slots             {}",
        rep.metrics.counter("padded_slots")
    );
    println!(
        "a2a bytes fresh/saved    {} / {}",
        rep.metrics.counter("a2a.fresh_bytes"),
        rep.metrics.counter("a2a.saved_bytes")
    );

    let q = dice::quality::evaluate(&ctx.rt, &ctx.bank, &rep.samples, &ctx.refs)?;
    println!(
        "served-sample quality    FID-proxy {:.2}  IS {:.2}  precision {:.2}",
        q.fid, q.is_score, q.precision
    );
    Ok(())
}
