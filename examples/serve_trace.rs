//! END-TO-END SERVING DRIVER: replay workload scenarios through the
//! full serving stack — scenario generator → admission control →
//! dynamic batcher (shape buckets) → serve loop → p50/p95/p99 latency,
//! throughput and SLO goodput per strategy.
//!
//! By default every (scenario × strategy) cell runs in simulation mode
//! (cost-model virtual time at the paper's XL / 8×4090 scale), so this
//! example works on a clean checkout with no artifacts. When the AOT
//! artifacts exist (`make artifacts` / `python -m compile.aot`), the
//! driver additionally serves one trace with REAL numerics through the
//! expert-parallel engine and reports the quality of the actually
//! served samples.
//!
//!     cargo run --release --example serve_trace -- --requests 256 --rate 2.0 --slo 60

use dice::cli::Args;
use dice::config::{hardware_profile, model_preset, DiceOptions, Strategy};
use dice::coordinator::{Engine, EngineConfig};
use dice::exp::Ctx;
use dice::netsim::CostModel;
use dice::server::{
    comparison_table, fault_preset, serve_fleet, serve_scenarios, AdmissionPolicy, BatchPolicy,
    FleetConfig, RouterKind, ServeConfig, ServeReport, SimExecutor,
};
use dice::workload::Scenario;

fn main() -> anyhow::Result<()> {
    let a = Args::parse();
    let n_requests = a.usize_or("requests", 256);
    let rate = a.f64_or("rate", 2.0);
    let steps = a.usize_or("steps", 50);
    let devices = a.usize_or("devices", 8);
    let slo = a.f64_or("slo", 60.0);
    let seed = a.u64_or("seed", 42);

    let cm = CostModel::new(
        model_preset(&a.str_or("model", "xl"))?,
        hardware_profile(&a.str_or("hw", "rtx4090_pcie"))?,
    );
    let policy = BatchPolicy {
        max_global: a.usize_or("max-batch", 32),
        max_wait: a.f64_or("max-wait", 3.0),
    };
    let cfg = ServeConfig::new(policy, steps, 7)
        .with_slo(slo)
        .with_admission(AdmissionPolicy::bounded(a.usize_or("queue-cap", 256)));

    let scenarios = [
        Scenario::parse("steady", rate)?,
        Scenario::parse("diurnal", rate)?,
        Scenario::burst_recovery(64, rate), // larger burst than the preset
    ];
    let strategies = [
        ("sync_ep", Strategy::SyncEp, DiceOptions::none()),
        ("displaced_ep", Strategy::DisplacedEp, DiceOptions::none()),
        ("dice", Strategy::Interweaved, DiceOptions::dice()),
    ];

    println!(
        "serving {n_requests} requests/scenario on {devices} devices @ {} / {} \
         ({steps} steps, SLO {slo}s, virtual time)...",
        cm.model.name, cm.hw.name
    );
    // identical trace per scenario so strategies compete fairly
    let traces: Vec<_> = scenarios
        .iter()
        .map(|s| s.trace(n_requests, cm.model.n_classes, seed))
        .collect();
    // per strategy, all scenarios serve concurrently on the worker pool
    // (DESIGN.md §8; virtual time keeps the fan-out deterministic)
    let mut indexed = Vec::new();
    for (ti, (_, strategy, opts)) in strategies.iter().enumerate() {
        let ex = SimExecutor::new(cm.clone(), *strategy, *opts, devices);
        let reps = serve_scenarios(&ex, &traces, cfg)?;
        for (si, rep) in reps.into_iter().enumerate() {
            indexed.push((si, ti, rep));
        }
    }
    indexed.sort_by_key(|t| (t.0, t.1)); // scenario-major, as served serially
    let rows: Vec<(String, String, ServeReport)> = indexed
        .into_iter()
        .map(|(si, ti, rep)| {
            (
                scenarios[si].name().to_string(),
                strategies[ti].0.to_string(),
                rep,
            )
        })
        .collect();
    comparison_table(
        &format!(
            "Serving comparison — {} on {}x {} (virtual time)",
            cm.model.name, devices, cm.hw.name
        ),
        &rows,
    )
    .print();

    // Fleet pass: the same burst trace through a multi-replica fleet
    // (server::fleet, DESIGN.md §14), with per-replica traces — which
    // replica served each batch — and per-replica utilisation lines.
    let replicas = a.usize_or("replicas", 3);
    let router = RouterKind::parse(&a.str_or("router", "least-loaded"))?;
    let fleet_trace = scenarios[2].trace(n_requests, cm.model.n_classes, seed);
    let horizon = fleet_trace.last().map_or(0.0, |r| r.arrival);
    let fleet_cfg = FleetConfig::new(replicas, router, cfg)
        .with_faults(fault_preset(&a.str_or("fault", "slow-replica"), replicas, horizon)?);
    let ex = SimExecutor::new(cm.clone(), Strategy::SyncEp, DiceOptions::none(), devices);
    let fleet = serve_fleet(&ex, &fleet_trace, &fleet_cfg)?;
    println!(
        "\n== fleet serve: {} on {replicas} replicas ({}) ==",
        scenarios[2].name(),
        router.name()
    );
    let shown = fleet.report.batches.len().min(12);
    for b in &fleet.report.batches[..shown] {
        println!(
            "  t={:>7.3}s replica {} batch of {:>2} (bucket {:>2}) lat {:>6.3}s",
            b.start,
            b.replica,
            b.request_ids.len(),
            b.global_batch,
            b.end - b.start
        );
    }
    if fleet.report.batches.len() > shown {
        println!("  ... {} more batches", fleet.report.batches.len() - shown);
    }
    for s in &fleet.per_replica {
        println!("  {}", s.line());
    }
    println!("  {}", fleet.summary_line());

    // Optional real-numerics pass when the AOT artifacts are present.
    match Ctx::open() {
        Err(e) => println!(
            "\n(real-numerics serve skipped: {e:#}; build the artifacts \
             with `cd python && python -m compile.aot --out-dir ../artifacts`)"
        ),
        Ok(ctx) => {
            let strategy = Strategy::parse(&a.str_or("strategy", "interweaved"))?;
            let eng = Engine::new(
                &ctx.rt,
                &ctx.bank,
                EngineConfig {
                    strategy,
                    opts: DiceOptions::dice().with_warmup(4),
                    devices: 4,
                },
            )?;
            let trace = Scenario::steady(rate).trace(
                a.usize_or("real-requests", 96),
                ctx.rt.model.n_classes,
                seed,
            );
            let t0 = std::time::Instant::now();
            let rep = dice::server::serve(&eng, &cm, &trace, policy, steps, 7)?;
            let wall = t0.elapsed().as_secs_f64();
            println!("\n== real-numerics serve ({}) ==", strategy.name());
            println!("host wall-clock          {wall:.1}s");
            println!("{}", rep.summary_line());
            println!(
                "padded slots             {}",
                rep.metrics.counter("padded_slots")
            );
            println!(
                "a2a bytes fresh/saved    {} / {}",
                rep.metrics.counter("a2a.fresh_bytes"),
                rep.metrics.counter("a2a.saved_bytes")
            );
            let q = dice::quality::evaluate(&ctx.rt, &ctx.bank, &rep.samples, &ctx.refs)?;
            println!(
                "served-sample quality    FID-proxy {:.2}  IS {:.2}  precision {:.2}",
                q.fid, q.is_score, q.precision
            );
        }
    }
    Ok(())
}
